"""Online λ-refresh lane: hot-swap parity, epoch-fence invariants,
drift regression, and the pure update rules (serving/refresh.py).

The headline contract, asserted here three ways: a hot-swapped
predictor generation serves BITWISE what a cold engine started from
that generation serves — for every family, across every pipeline phase
a swap can land in — and the swap itself never recompiles (per-bucket
jit caches stay at exactly the warmed executable) and never adds a
dispatch (executable_calls stays one per flushed micro-batch).

Everything runs on the FrozenClock: no deadline flush ever fires, so
batch composition is a pure function of the stream and refresh-on /
refresh-off / hot-vs-cold comparisons are bitwise-valid on any box.

The property layer (hypothesis, import-guarded like test_admission.py)
proves the refresh invariants: epoch monotonicity (failed swaps never
move the epoch), KNN ring append/evict parity against a from-scratch
fit on the trailing window, dual-target projection properties, and
rollback-after-swap restoring the pre-swap state bitwise.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import FrozenClock

from repro.core.predictors import (
    KNNLambdaPredictor,
    LinearLambdaPredictor,
    MeanLambdaPredictor,
    MLPLambdaPredictor,
    knn_predict,
    predictor_state,
    with_state,
)
from repro.data.synthetic import DriftSpec
from repro.serving import (
    RefreshLane,
    Scenario,
    ServingEngine,
    dual_refresh_targets,
    knn_ring_update,
    make_drift_stream,
    make_stream,
    ridge_refresh,
    running_mean_update,
)

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                              # pragma: no cover
    given = None

TAG = "arch"
D_COV, K = 10, 4


def _fit(family, rng, *, d=D_COV, K=K, n=48):
    X = rng.normal(size=(n, d)).astype(np.float32)
    lam = np.abs(rng.normal(size=(n, K))).astype(np.float32)
    if family == "knn":
        return KNNLambdaPredictor.fit(X, lam, k=5)
    if family == "linear":
        return LinearLambdaPredictor.fit(jnp.asarray(X), jnp.asarray(lam))
    if family == "mean":
        return MeanLambdaPredictor.fit(X, lam)
    if family == "mlp":
        return MLPLambdaPredictor.fit(X, lam, d_hidden=16, num_steps=30)
    raise ValueError(family)


def _stream(n=32, *, K_req=K, b_frac=0.25, seed=0, m1=96, m2=8):
    """Stationary covariate stream; b_frac=0.25 makes exposure
    shortfall near-certain, so a refresh always has something to
    publish."""
    return make_drift_stream(
        DriftSpec(kind="none"), tag=TAG, n_requests=n, m1=m1, m2=m2,
        K=K_req, d_cov=D_COV, b_frac=b_frac, seed=seed)


def _engine(pred, *, depth=0, max_batch=4, **kw):
    eng = ServingEngine(max_batch=max_batch, max_wait_ms=1e9,
                        pipeline_depth=depth, clock=FrozenClock(), **kw)
    eng.register_predictor(TAG, pred, d_cov=D_COV)
    return eng


def _assert_same(got, ref):
    np.testing.assert_array_equal(got.perm, ref.perm)
    np.testing.assert_array_equal(got.exposure, ref.exposure)
    assert got.utility == ref.utility
    assert got.compliant == ref.compliant
    assert got.bucket == ref.bucket


def _host_state(eng, tag=TAG):
    return jax.device_get(eng.predictor_state_of(tag))


# ---------------------------------------------------------------------------
# Hot-swap parity: refreshed serving == cold engine with that state
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", ["mean", "knn", "linear", "mlp"])
def test_hot_swap_matches_cold_engine(family):
    """Serve, refresh (real telemetry -> real swap), serve again: the
    post-swap half must be bitwise what a COLD engine built from the
    swapped state serves — and the swap costs zero recompiles and zero
    extra dispatches."""
    rng = np.random.default_rng(0)
    pred = _fit(family, rng)
    reqs = _stream(32)
    first, second = reqs[:16], reqs[16:]

    eng = _engine(pred)
    lane = RefreshLane(eng, eta=0.5, min_samples=4, mlp_steps=10)
    eng.warmup(reqs)
    out1 = eng.serve_stream(first, warmup=False)
    assert all(r.epoch == 0 for r in out1)
    assert lane.pending(TAG) == 16

    rep = lane.refresh(TAG)[TAG]
    assert rep["swapped"] and rep["epoch"] == 1 and rep["n"] == 16
    assert rep["max_shortfall"] > 0.0
    assert eng.predictor_epoch(TAG) == 1

    out2 = eng.serve_stream(second, warmup=False)
    assert all(r.epoch == 1 for r in out2)
    # the no-recompile / single-dispatch contracts survived the swap
    m = eng.metrics
    assert m.compiles_post_warmup == 0
    assert m.executable_calls == m.batches
    sizes = eng.jit_cache_sizes()
    assert sizes and all(v == 1 for v in sizes.values()), sizes

    cold = _engine(with_state(pred, _host_state(eng)))
    ref = {r.rid: r for r in cold.serve_stream(second)}
    for r in out2:
        _assert_same(r, ref[r.rid])


def test_hot_swap_with_bucket_padded_K():
    """Requests carrying fewer constraints than the predictor emits
    (K_req < K_pred): telemetry rows are zero-padded to the predictor
    width, and post-swap parity with the cold engine still holds."""
    rng = np.random.default_rng(1)
    pred = _fit("knn", rng)                      # emits K=4
    reqs = _stream(24, K_req=3)                  # requests carry K=3

    eng = _engine(pred)
    lane = RefreshLane(eng, eta=0.5, min_samples=4)
    eng.warmup(reqs)
    eng.serve_stream(reqs[:12], warmup=False)
    assert lane.refresh(TAG)[TAG]["swapped"]
    out = eng.serve_stream(reqs[12:], warmup=False)
    assert eng.metrics.compiles_post_warmup == 0

    cold = _engine(with_state(pred, _host_state(eng)))
    ref = {r.rid: r for r in cold.serve_stream(reqs[12:])}
    for r in out:
        _assert_same(r, ref[r.rid])


@pytest.mark.parametrize("depth", [0, 1, 2])
@pytest.mark.parametrize("swap_at", [0, 2, 5])
def test_mid_stream_swap_never_tears_a_batch(depth, swap_at):
    """A swap landing at any pipeline phase — before the stream, with a
    queue partially filled, with batches in flight — produces results
    that are each ENTIRELY one generation: every result's epoch labels
    a payload bitwise equal to the matching cold engine's. swap_at=2
    lands mid-queue (max_batch=4), so the already-queued requests must
    flush AGAINST THE NEW generation (the fence flips at the batch
    boundary, not at enqueue)."""
    rng = np.random.default_rng(2)
    pred = _fit("knn", rng)
    state1 = predictor_state(_fit("knn", np.random.default_rng(99)))
    reqs = _stream(12)

    refs = {}
    for epoch, p in ((0, pred), (1, with_state(pred, state1))):
        refs[epoch] = {r.rid: r for r in _engine(p).serve_stream(reqs)}

    eng = _engine(pred, depth=depth)
    eng.warmup(reqs)
    results = []
    for i, r in enumerate(reqs):
        if i == swap_at:
            assert eng.swap_predictor(TAG, state1) == 1
        results += eng.submit(r)
    results += eng.drain()
    assert sorted(r.rid for r in results) == list(range(12))
    assert eng.metrics.compiles_post_warmup == 0
    for r in results:
        assert r.epoch in (0, 1)
        _assert_same(r, refs[r.epoch][r.rid])
    # the swap landed before any batch containing a later submit
    assert all(r.epoch == 1 for r in results if r.rid >= swap_at + 4)
    eng.close()


def test_rollback_restores_pre_swap_serving_bitwise():
    """rollback() re-publishes the pre-swap generation as a NEW epoch;
    serving afterwards is bitwise the original engine's."""
    rng = np.random.default_rng(3)
    pred = _fit("linear", rng)
    reqs = _stream(24)
    ref = {r.rid: r for r in _engine(pred).serve_stream(reqs[16:])}

    eng = _engine(pred)
    lane = RefreshLane(eng, min_samples=4)
    eng.warmup(reqs)
    before = _host_state(eng)
    eng.serve_stream(reqs[:16], warmup=False)
    assert lane.refresh(TAG)[TAG]["swapped"]
    assert eng.predictor_epoch(TAG) == 1
    assert lane.rollback(TAG) == 2               # fence applies to rollback too
    after = _host_state(eng)
    for k_ in before:
        np.testing.assert_array_equal(np.asarray(before[k_]),
                                      np.asarray(after[k_]))
    out = eng.serve_stream(reqs[16:], warmup=False)
    assert all(r.epoch == 2 for r in out)
    for r in out:
        _assert_same(r, ref[r.rid])
    assert eng.metrics.compiles_post_warmup == 0


def test_rollback_without_prior_swap_raises():
    eng = _engine(_fit("mean", np.random.default_rng(4)))
    lane = RefreshLane(eng)
    with pytest.raises(KeyError, match="no pre-swap state"):
        lane.rollback(TAG)


# ---------------------------------------------------------------------------
# Swap validation: refusals leave serving untouched
# ---------------------------------------------------------------------------


def test_swap_rejects_bad_state_and_keeps_serving_last_good():
    rng = np.random.default_rng(5)
    pred = _fit("knn", rng)
    reqs = _stream(8)
    eng = _engine(pred)
    eng.warmup(reqs)
    good = _host_state(eng)

    with pytest.raises(ValueError, match="state keys"):
        eng.swap_predictor(TAG, {"X_db": good["X_db"]})
    with pytest.raises(ValueError, match="frozen"):
        eng.swap_predictor(TAG, {"X_db": good["X_db"],
                                 "lam_db": good["lam_db"][:-1]})
    poisoned = {"X_db": good["X_db"],
                "lam_db": np.full_like(good["lam_db"], np.nan)}
    with pytest.raises(ValueError, match="poisoned"):
        eng.swap_predictor(TAG, poisoned)
    with pytest.raises(KeyError, match="no predictor registered"):
        eng.swap_predictor("nope", good)

    # every refusal left the generation untouched: epoch 0, bitwise
    # the cold engine's results
    assert eng.predictor_epoch(TAG) == 0
    ref = {r.rid: r for r in _engine(pred).serve_stream(reqs)}
    for r in eng.serve_stream(reqs, warmup=False):
        assert r.epoch == 0
        _assert_same(r, ref[r.rid])


def test_swap_rejects_duck_typed_predictor_without_state():
    """A predictor family outside STATE_FIELDS serves fine (closed
    over, pre-refresh behavior) but cannot be hot-swapped — the engine
    says so instead of silently retracing."""

    class Opaque:
        def predict(self, X):
            return jnp.zeros(X.shape[:-1] + (K,), jnp.float32)

    eng = ServingEngine(max_batch=4, pipeline_depth=0, clock=FrozenClock())
    eng.register_predictor("opaque", Opaque(), d_cov=D_COV)
    with pytest.raises(ValueError, match="refreshable state"):
        eng.swap_predictor("opaque", {})


# ---------------------------------------------------------------------------
# Acceptance: mixed 256-request stream, swaps mid-stream, zero recompiles
# ---------------------------------------------------------------------------


def test_mixed_stream_hot_swaps_zero_recompiles_single_dispatch():
    """The PR's acceptance stream: 256 mixed requests (two predictor
    archs + raw-lam, three geometries), refresh lane publishing between
    chunks. Across every swap: zero post-warmup compiles, per-bucket
    jit caches stay at 1, and executable_calls stays exactly one per
    flushed micro-batch."""
    rng = np.random.default_rng(6)
    d = D_COV
    knn = KNNLambdaPredictor.fit(
        rng.normal(size=(64, d)).astype(np.float32),
        np.abs(rng.normal(size=(64, K))).astype(np.float32), k=5)
    lin = LinearLambdaPredictor.fit(
        jnp.asarray(rng.normal(size=(64, d)), jnp.float32),
        jnp.asarray(np.abs(rng.normal(size=(64, K))), jnp.float32))
    mix = (
        Scenario("feed", m1=500, m2=50, K=K, weight=3.0, tag="knn_arch",
                 d_cov=d, b_frac=0.3),
        Scenario("cov", m1=120, m2=8, K=K, weight=2.0, tag="lin_arch",
                 d_cov=d, b_frac=0.3),
        Scenario("strip", m1=1000, m2=20, K=3, weight=2.0),   # raw-lam
    )
    reqs = make_stream(mix, n_requests=256, seed=7)

    eng = ServingEngine(max_batch=16, max_wait_ms=1e9, pipeline_depth=1,
                        clock=FrozenClock())
    eng.register_predictor("knn_arch", knn, d_cov=d)
    eng.register_predictor("lin_arch", lin, d_cov=d)
    lane = RefreshLane(eng, min_samples=4)
    eng.warmup(reqs)
    results, epochs_seen = [], []
    for i in range(0, 256, 64):
        results += eng.serve_stream(reqs[i:i + 64], warmup=False)
        for tag, rep in lane.refresh().items():
            if rep["swapped"]:
                epochs_seen.append((tag, rep["epoch"]))
    assert sorted(r.rid for r in results) == list(range(256))

    m = eng.metrics
    rs = m.refresh_summary()
    assert rs["swaps"] >= 2 and len(epochs_seen) == rs["swaps"]
    assert m.compiles_post_warmup == 0
    assert m.executable_calls == m.batches
    assert m.summary()["dispatches_per_batch"] == 1.0
    sizes = eng.jit_cache_sizes()
    assert sizes and all(v == 1 for v in sizes.values()), sizes
    # raw-lam results never ride a predictor generation
    by_rid = {r.rid: r for r in results}
    for req in reqs:
        if req.lam is not None:
            assert by_rid[req.rid].epoch == 0
    # per-tag epochs strictly increased across swaps
    for tag in ("knn_arch", "lin_arch"):
        tag_epochs = [e for t, e in epochs_seen if t == tag]
        assert tag_epochs == sorted(tag_epochs)
        assert eng.predictor_epoch(tag) == (tag_epochs[-1]
                                            if tag_epochs else 0)
    eng.close()


def test_fused_executor_swap_keeps_single_kernel_launch():
    """The fused-executor contract across a swap: every flushed batch
    still carries exactly ONE Pallas kernel launch, and the post-swap
    results match the cold fused engine bitwise."""
    rng = np.random.default_rng(8)
    lin = LinearLambdaPredictor.fit(
        jnp.asarray(rng.normal(size=(48, D_COV)), jnp.float32),
        jnp.asarray(np.abs(rng.normal(size=(48, K))), jnp.float32))
    reqs = _stream(12, m1=128, m2=16)

    eng = _engine(lin, executor="fused")
    lane = RefreshLane(eng, min_samples=4)
    eng.warmup(reqs)
    eng.serve_stream(reqs[:6], warmup=False)
    assert lane.refresh(TAG)[TAG]["swapped"]
    out = eng.serve_stream(reqs[6:], warmup=False)

    m = eng.metrics
    assert m.compiles_post_warmup == 0
    assert m.kernel_launches == m.batches
    assert m.summary()["kernel_launches_per_batch"] == 1.0
    cold = _engine(with_state(lin, _host_state(eng)), executor="fused")
    ref = {r.rid: r for r in cold.serve_stream(reqs[6:])}
    for r in out:
        _assert_same(r, ref[r.rid])


# ---------------------------------------------------------------------------
# Drift regression: refresh-on beats refresh-off; neutral when stationary
# ---------------------------------------------------------------------------


def _drift_run(reqs, *, refresh_on, eta=1.0, every=32, knn_seed=9):
    """Serve `reqs` in chunks, refreshing between chunks when on.
    Returns (accumulated shortfall vs the requests' REAL thresholds,
    engine, lane)."""
    rng = np.random.default_rng(knn_seed)
    pred = KNNLambdaPredictor.fit(
        rng.normal(size=(64, D_COV)).astype(np.float32),
        np.zeros((64, K), np.float32), k=5)     # fit in the compliant era
    eng = _engine(pred, max_batch=8)
    lane = RefreshLane(eng, eta=eta, min_samples=8) if refresh_on else None
    eng.warmup(reqs)
    results = []
    for i in range(0, len(reqs), every):
        results += eng.serve_stream(reqs[i:i + every], warmup=False)
        if lane is not None:
            lane.refresh()
    by_rid = {r.rid: r for r in reqs}
    shortfall = sum(
        float(np.clip(by_rid[r.rid].b - r.exposure, 0.0, None).sum())
        for r in results)
    return shortfall, results, eng, lane


def test_refresh_reduces_shortfall_under_tighten_drift():
    """The drift acceptance criterion: under mid-stream constraint
    tightening, the refresh lane's dual-subgradient updates strictly
    reduce accumulated compliance shortfall vs the frozen predictor —
    with zero recompiles along the way."""
    spec = DriftSpec(kind="tighten", magnitude=8.0, start=0.25, end=0.75)
    reqs = make_drift_stream(spec, tag=TAG, n_requests=256, m1=128, m2=16,
                             K=K, d_cov=D_COV, b_frac=0.03, seed=10)
    off, _, eng_off, _ = _drift_run(reqs, refresh_on=False)
    on, _, eng_on, lane = _drift_run(reqs, refresh_on=True)
    assert on < off                              # strictly reduces
    assert eng_on.metrics.refresh_summary()["swaps"] >= 1
    assert eng_on.metrics.compiles_post_warmup == 0
    assert eng_off.metrics.compiles_post_warmup == 0
    sizes = eng_on.jit_cache_sizes()
    assert all(v == 1 for v in sizes.values()), sizes


def test_refresh_is_bitwise_neutral_on_stationary_compliant_stream():
    """The stationarity gate: on a stationary stream with no dual
    pressure — compliant (no shortfall) AND served with λ̂ = 0 (no
    decay pressure: the symmetric side of the gate only counts
    over-satisfaction on rows whose served λ̂ > 0) — the lane never
    publishes, so refresh-on serving is bitwise identical to
    refresh-off."""
    reqs = make_drift_stream(
        DriftSpec(kind="none"), tag=TAG, n_requests=96, m1=128, m2=16,
        K=K, d_cov=D_COV, topic_rate=0.45, b_frac=0.01, seed=11)
    rng = np.random.default_rng(12)
    pred = KNNLambdaPredictor.fit(
        rng.normal(size=(64, D_COV)).astype(np.float32),
        np.zeros((64, K), np.float32), k=5)

    def run(on):
        eng = _engine(pred, max_batch=8)
        lane = RefreshLane(eng, min_samples=8) if on else None
        eng.warmup(reqs)
        results = []
        for i in range(0, len(reqs), 16):
            results += eng.serve_stream(reqs[i:i + 16], warmup=False)
            if lane is not None:
                for rep in lane.refresh().values():
                    assert not rep["swapped"]
                    assert rep["reason"] in ("no-pressure",
                                             "below-min-samples")
        return results, eng

    ref, _ = run(False)
    # precondition that makes the gate testable: this configuration is
    # fully compliant without any refresh
    assert all(r.compliant for r in ref)
    got, eng = run(True)
    assert eng.metrics.refresh_summary()["swaps"] == 0
    assert eng.predictor_epoch(TAG) == 0
    ref_by_rid = {r.rid: r for r in ref}
    assert len(got) == len(ref)
    for r in got:
        assert r.epoch == 0
        _assert_same(r, ref_by_rid[r.rid])


def test_quantized_knn_refresh_never_serves_stale_scales():
    """Satellite contract for the quantized db under the refresh lane:
    a mid-stream ring-write swap repacks exactly the touched slabs, so
    the published (X_q, q_scale, y2_q) is bitwise what a from-scratch
    pack of the updated f32 db would produce — a swap can never leave
    a slab's scale predating its rows."""
    from repro.core.predictors import pack_knn_db

    reqs = _stream(48, seed=31)
    rng = np.random.default_rng(32)
    pred = KNNLambdaPredictor.fit(
        rng.normal(size=(64, D_COV)).astype(np.float32),
        np.abs(rng.normal(size=(64, K))).astype(np.float32),
        k=5).quantized(mode="int8", slab=16)

    eng = _engine(pred, max_batch=8)
    lane = RefreshLane(eng, min_samples=8)
    eng.warmup(reqs)
    swaps = 0
    for i in range(0, len(reqs), 16):
        eng.serve_stream(reqs[i:i + 16], warmup=False)
        rep = lane.refresh()[TAG]
        if not rep["swapped"]:
            continue
        swaps += 1
        state = eng.predictor_state_of(TAG)
        X_q, q_scale, y2_q = pack_knn_db(
            jnp.asarray(state["X_db"]), mode="int8", slab=16)
        for name, live, full in (("X_q", state["X_q"], X_q),
                                 ("q_scale", state["q_scale"], q_scale),
                                 ("y2_q", state["y2_q"], y2_q)):
            assert (np.asarray(live) == np.asarray(full)).all(), (
                f"{name} diverged from a from-scratch repack after "
                f"swap {swaps}")
    assert swaps >= 1, "the shortfall-heavy stream never published"
    eng.close()


def test_refresh_decays_oversatisfied_lambda_toward_zero():
    """The symmetric side of the gate: a predictor serving POSITIVE λ̂
    on a compliant stationary stream is over-boosting — exposure
    exceeds the thresholds while utility pays for the boost. The lane
    must now publish (decay pressure), and each generation's predicted
    λ̂ must move toward 0, never below it."""
    reqs = make_drift_stream(
        DriftSpec(kind="none"), tag=TAG, n_requests=96, m1=128, m2=16,
        K=K, d_cov=D_COV, topic_rate=0.45, b_frac=0.01, seed=21)
    rng = np.random.default_rng(22)
    X_fit = rng.normal(size=(64, D_COV)).astype(np.float32)
    pred = KNNLambdaPredictor.fit(
        X_fit, 0.5 * np.abs(rng.normal(size=(64, K))).astype(np.float32),
        k=5)
    probe = jnp.asarray(X_fit[:16])

    eng = _engine(pred, max_batch=8)
    lane = RefreshLane(eng, min_samples=8, eta=0.5)
    eng.warmup(reqs)
    means = [float(np.mean(np.asarray(
        with_state(pred, eng.predictor_state_of(TAG)).predict(probe))))]
    saw_decay_swap = False
    for i in range(0, len(reqs), 16):
        eng.serve_stream(reqs[i:i + 16], warmup=False)
        rep = lane.refresh()[TAG]
        if rep["swapped"]:
            assert rep["max_decay"] > 0.0
            saw_decay_swap = True
        means.append(float(np.mean(np.asarray(
            with_state(pred, eng.predictor_state_of(TAG))
            .predict(probe)))))
    assert saw_decay_swap, "no decay-driven refresh ever published"
    # λ̂ relaxes toward 0 under sustained over-satisfaction and the
    # projection keeps it non-negative throughout
    assert means[-1] < means[0]
    final = np.asarray(
        with_state(pred, eng.predictor_state_of(TAG)).predict(probe))
    assert (final >= 0.0).all()
    eng.close()


# ---------------------------------------------------------------------------
# Pure update rules (deterministic)
# ---------------------------------------------------------------------------


def test_knn_ring_update_wraps_and_evicts_oldest():
    X_db = np.arange(4, dtype=np.float32)[:, None]       # rows 0..3
    lam_db = 10.0 * np.arange(4, dtype=np.float32)[:, None]
    X1 = np.array([[100.0], [101.0], [102.0]], np.float32)
    X_db, lam_db, cur = knn_ring_update(X_db, lam_db, X1, 2 * X1, 0)
    np.testing.assert_array_equal(X_db[:, 0], [100.0, 101.0, 102.0, 3.0])
    assert cur == 3
    X2 = np.array([[200.0], [201.0]], np.float32)
    X_db, lam_db, cur = knn_ring_update(X_db, lam_db, X2, 2 * X2, cur)
    np.testing.assert_array_equal(X_db[:, 0], [201.0, 101.0, 102.0, 200.0])
    assert cur == 1
    # a burst larger than the db: only the newest n_train survive
    X3 = np.arange(300.0, 306.0, dtype=np.float32)[:, None]
    X_db, lam_db, cur = knn_ring_update(X_db, lam_db, X3, 2 * X3, cur)
    assert sorted(X_db[:, 0]) == [302.0, 303.0, 304.0, 305.0]
    np.testing.assert_array_equal(lam_db, 2 * X_db)


def test_knn_ring_update_empty_batch_is_identity():
    X_db = np.ones((3, 2), np.float32)
    lam_db = np.ones((3, 1), np.float32)
    X2, l2, cur = knn_ring_update(X_db, lam_db,
                                  np.zeros((0, 2), np.float32),
                                  np.zeros((0, 1), np.float32), 1)
    np.testing.assert_array_equal(X2, X_db)
    assert cur == 1


def test_ridge_refresh_anchor_limits():
    rng = np.random.default_rng(13)
    W = rng.normal(size=(3, 5)).astype(np.float32)
    c = rng.normal(size=3).astype(np.float32)
    X = rng.normal(size=(64, 5)).astype(np.float32)
    Y = rng.normal(size=(64, 3)).astype(np.float32)
    # mu -> huge: the anchor wins, weights barely move
    W2, c2 = ridge_refresh(W, c, X, Y, mu=1e9)
    np.testing.assert_allclose(W2, W, atol=1e-4)
    np.testing.assert_allclose(c2, c, atol=1e-4)
    # mu -> tiny with ample data: the least-squares fit wins
    W3, c3 = ridge_refresh(W, c, X, Y, mu=1e-6)
    Xa = np.concatenate([X, np.ones((64, 1), np.float32)], axis=1)
    ref, *_ = np.linalg.lstsq(Xa.astype(np.float64),
                              Y.astype(np.float64), rcond=None)
    np.testing.assert_allclose(W3, ref.T[:, :5], atol=1e-4)
    np.testing.assert_allclose(c3, ref.T[:, 5], atol=1e-4)


def test_running_mean_update_is_weighted_average():
    mean = np.array([1.0, 3.0], np.float32)
    Y = np.array([[2.0, 0.0], [4.0, 0.0]], np.float32)
    new, w = running_mean_update(mean, 2.0, Y)
    np.testing.assert_allclose(new, [(2 * 1 + 6) / 4, (2 * 3 + 0) / 4])
    assert w == 4.0


def test_dual_refresh_targets_direction_and_projection():
    lam = np.array([0.5, 0.0, 2.0], np.float32)
    b = np.array([1.0, 1.0, 0.0], np.float32)
    expo = np.array([0.2, 1.0, 5.0], np.float32)   # short / met / surplus
    t = dual_refresh_targets(lam, b, expo, eta=1.0)
    assert t[0] == np.float32(0.5 + 0.8)           # shortfall raises
    assert t[1] == 0.0                             # met: unchanged
    assert t[2] == 0.0                             # surplus: projected to 0
    assert t.dtype == np.float32


# ---------------------------------------------------------------------------
# Property layer (hypothesis; skipped visibly when unavailable)
# ---------------------------------------------------------------------------


if given is not None:
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")

    @given(st.integers(0, 10 ** 6), st.floats(0.05, 4.0))
    def test_dual_targets_properties(seed, eta):
        """Targets are nonnegative, move WITH the subgradient (up on
        shortfall, down on surplus), and are the identity exactly where
        the constraint is met."""
        rng = np.random.default_rng(seed)
        lam = np.abs(rng.normal(size=8)).astype(np.float32)
        b = rng.uniform(0, 2, 8).astype(np.float32)
        expo = rng.uniform(0, 2, 8).astype(np.float32)
        expo[:2] = b[:2]                           # exactly-met rows
        t = dual_refresh_targets(lam, b, expo, eta=eta)
        assert (t >= 0).all()
        np.testing.assert_array_equal(t[:2], lam[:2])
        short = b > expo
        assert (t[short] >= lam[short]).all()
        assert (t[~short] <= lam[~short]).all()

    @given(st.data())
    def test_knn_ring_matches_trailing_window_fit(data):
        """Append/evict parity: after any sequence of ring updates the
        db holds exactly the trailing n_train rows of the full history
        (initial db then appends), and the KNN estimator on the ring db
        agrees with a from-scratch fit on that trailing window."""
        n_train = data.draw(st.integers(2, 5), label="n_train")
        d = data.draw(st.integers(1, 3), label="d")
        seed = data.draw(st.integers(0, 10 ** 6), label="seed")
        rng = np.random.default_rng(seed)
        X_db = rng.normal(size=(n_train, d)).astype(np.float32)
        lam_db = rng.normal(size=(n_train, 2)).astype(np.float32)
        hist_X, hist_lam = list(X_db), list(lam_db)
        cursor = 0
        for _ in range(data.draw(st.integers(1, 3), label="batches")):
            m = data.draw(st.integers(0, 2 * n_train), label="m")
            Xn = rng.normal(size=(m, d)).astype(np.float32)
            ln = rng.normal(size=(m, 2)).astype(np.float32)
            X_db, lam_db, cursor = knn_ring_update(X_db, lam_db, Xn, ln,
                                                   cursor)
            hist_X += list(Xn)
            hist_lam += list(ln)
        win_X = np.stack(hist_X[-n_train:])
        win_lam = np.stack(hist_lam[-n_train:])
        ring = np.concatenate([X_db, lam_db], axis=1)
        win = np.concatenate([win_X, win_lam], axis=1)
        np.testing.assert_array_equal(
            ring[np.lexsort(ring.T[::-1])], win[np.lexsort(win.T[::-1])])
        Xq = rng.normal(size=(3, d)).astype(np.float32)
        k = min(2, n_train)
        p_ring = np.asarray(knn_predict(
            jnp.asarray(X_db), jnp.asarray(lam_db), jnp.asarray(Xq), k=k))
        p_win = np.asarray(knn_predict(
            jnp.asarray(win_X), jnp.asarray(win_lam), jnp.asarray(Xq), k=k))
        np.testing.assert_allclose(p_ring, p_win, rtol=2e-5, atol=2e-6)

    @given(st.lists(st.sampled_from(["good", "nan", "shape", "keys"]),
                    max_size=8))
    def test_epoch_monotone_and_increments_only_on_success(ops):
        """The epoch is monotone and moves EXACTLY on successful swaps
        — every refusal (poisoned, wrong shape, wrong keys) leaves it
        untouched."""
        pred = MeanLambdaPredictor.fit(np.zeros((2, 4), np.float32),
                                       np.ones((2, 3), np.float32))
        eng = ServingEngine(max_batch=4, pipeline_depth=0,
                            clock=FrozenClock())
        eng.register_predictor("m", pred, d_cov=4)
        epoch = 0
        bad = {"nan": {"mean_lam": np.array([np.nan, 0, 0], np.float32)},
               "shape": {"mean_lam": np.zeros(4, np.float32)},
               "keys": {"wrong": np.zeros(3, np.float32)}}
        for op in ops:
            if op == "good":
                eng.swap_predictor(
                    "m", {"mean_lam": np.full(3, epoch + 1.0, np.float32)})
                epoch += 1
            else:
                with pytest.raises(ValueError):
                    eng.swap_predictor("m", bad[op])
            assert eng.predictor_epoch("m") == epoch

    @given(st.integers(0, 10 ** 6),
           st.sampled_from(["knn", "linear", "mean"]))
    def test_rollback_restores_last_good_state_bitwise(seed, family):
        """refresh -> rollback round-trips the LIVE state bitwise, for
        any telemetry the refresh consumed."""
        rng = np.random.default_rng(seed)
        pred = _fit(family, rng, d=6, K=3, n=8)
        eng = ServingEngine(max_batch=4, pipeline_depth=0,
                            clock=FrozenClock())
        eng.register_predictor("t", pred, d_cov=6)
        lane = RefreshLane(eng, min_samples=4)
        before = jax.device_get(eng.predictor_state_of("t"))
        for _ in range(4):
            lane.observe("t", X=rng.normal(size=6).astype(np.float32),
                         lam=np.abs(rng.normal(size=3)).astype(np.float32),
                         exposure=np.zeros(3, np.float32),
                         b=np.ones(3, np.float32))
        assert lane.refresh("t")["t"]["swapped"]
        lane.rollback("t")
        after = jax.device_get(eng.predictor_state_of("t"))
        for key in before:
            np.testing.assert_array_equal(np.asarray(before[key]),
                                          np.asarray(after[key]))

else:                                            # keep the skip visible

    def test_refresh_property_layer_requires_hypothesis():
        pytest.importorskip("hypothesis")


# ---------------------------------------------------------------------------
# stop(final_refresh=True) vs an in-flight background refresh (regression)
# ---------------------------------------------------------------------------


def _observe_window(lane, tag, rng, n=4):
    """Buffer one telemetry window with guaranteed shortfall pressure."""
    for _ in range(n):
        lane.observe(tag, X=rng.normal(size=D_COV).astype(np.float32),
                     lam=np.abs(rng.normal(size=K)).astype(np.float32),
                     exposure=np.zeros(K, np.float32),
                     b=np.ones(K, np.float32))


def test_stop_final_refresh_never_races_inflight_pass():
    """Regression: stop(final_refresh=True) used to bounded-join the
    lane thread and could run the final refresh CONCURRENTLY with an
    in-flight background pass — both passes building on the same live
    state and double-publishing one telemetry window (a lost update:
    the later swap silently dropped the earlier window).

    The mean family makes the lost update observable exactly: each
    published window adds its row count to the running-mean weight, so
    weight_final = weight_0 + n_1 + n_2 iff the two windows were
    applied SEQUENTIALLY. A racing pair both building on weight_0
    would end at weight_0 + n_2.

    Deterministic schedule via publish_filter: the background pass
    blocks inside its publish (gate), the main thread buffers a second
    window and calls stop(final_refresh=True) from a helper thread —
    which must WAIT (not abandon the lane thread), and only after the
    gate opens run the final pass on the fresh window."""
    import threading

    rng = np.random.default_rng(0)
    eng = ServingEngine(max_batch=4, pipeline_depth=0, clock=FrozenClock())
    eng.register_predictor(TAG, _fit("mean", rng), d_cov=D_COV)

    entered = threading.Event()
    gate = threading.Event()
    inside, max_inside = [0], [0]
    ilock = threading.Lock()

    def publish_filter(tag, state):
        with ilock:
            inside[0] += 1
            max_inside[0] = max(max_inside[0], inside[0])
        entered.set()
        if not gate.wait(timeout=30.0):         # fail loud, never hang CI
            raise RuntimeError("gate never opened")
        with ilock:
            inside[0] -= 1
        return state

    lane = RefreshLane(eng, min_samples=4, publish_filter=publish_filter)
    w0 = lane._default_mean_weight
    _observe_window(lane, TAG, rng, n=4)        # window 1
    lane.start(interval_s=1e-3)
    assert entered.wait(timeout=30.0)           # pass 1 in flight, blocked

    _observe_window(lane, TAG, rng, n=6)        # window 2
    stopper = threading.Thread(
        target=lambda: lane.stop(final_refresh=True))
    stopper.start()
    stopper.join(timeout=0.3)
    assert stopper.is_alive()                   # stop WAITS for the pass
    gate.set()
    stopper.join(timeout=30.0)
    assert not stopper.is_alive()
    assert lane._thread is None                 # lane thread fully drained

    assert max_inside[0] == 1                   # passes never interleaved
    assert eng.predictor_epoch(TAG) == 2        # both windows published...
    assert lane._mean_weight[TAG] == w0 + 4 + 6  # ...sequentially: no
    assert eng.metrics.swaps == 2                # window was lost or doubled
    assert lane.pending(TAG) == 0
