"""Async double-buffered pipeline: sync/async result equivalence,
future-to-request association, backpressure, and graceful shutdown.

The pipeline must be a pure scheduling change: for any stream, the
pipelined engine (pipeline_depth >= 1) returns bitwise the same
perm/utility/exposure/compliance per rid as the synchronous engine
(pipeline_depth=0), differing only in when results materialize.
"""

import threading

import numpy as np
import pytest

from repro.core.constraints import dcg_discount
from repro.core.predictors import KNNLambdaPredictor
from repro.serving import (
    ExecutionPipeline,
    RankRequest,
    Scenario,
    ServingEngine,
    StagingRing,
    bucket_for,
    make_stream,
)


def _tiny_request(rid, m1=64, m2=8, K=2):
    rng = np.random.default_rng(rid)
    return RankRequest(
        rid=rid, u=rng.uniform(1, 5, m1).astype(np.float32),
        a=(rng.random((K, m1)) < 0.3).astype(np.float32),
        b=np.zeros(K, np.float32), m2=m2,
        lam=np.zeros(K, np.float32),
        gamma=np.asarray(dcg_discount(m2), np.float32))


def _mixed_stream(n=256, seed=4, d=12, K=5):
    """>= 2 archs, >= 3 geometries, predictor + raw-lam paths mixed."""
    rng = np.random.default_rng(seed)
    knn = KNNLambdaPredictor.fit(
        rng.normal(size=(64, d)).astype(np.float32),
        np.abs(rng.normal(size=(64, K))).astype(np.float32), k=5)
    mix = (
        Scenario("feed", m1=500, m2=50, K=K, weight=3.0, tag="knn", d_cov=d),
        Scenario("strip", m1=1000, m2=20, K=3, weight=2.0),
        Scenario("notif", m1=120, m2=8, K=3, weight=1.0),
    )
    return make_stream(mix, n_requests=n, seed=seed), ("knn", knn, d)


def _engine(depth, max_batch=16, max_wait_ms=2.0, predictor=None):
    eng = ServingEngine(max_batch=max_batch, max_wait_ms=max_wait_ms,
                        pipeline_depth=depth)
    if predictor is not None:
        tag, pred, d = predictor
        eng.register_predictor(tag, pred, d_cov=d)
    return eng


# ---------------------------------------------------------------------------
# Sync/async equivalence on a mixed 256-request stream
# ---------------------------------------------------------------------------


def test_sync_async_equivalence_mixed_256_stream():
    reqs, predictor = _mixed_stream(256)
    ref = {r.rid: r
           for r in _engine(0, predictor=predictor).serve_stream(reqs)}
    for depth in (1, 2, 4):
        got = {r.rid: r
               for r in _engine(depth, predictor=predictor).serve_stream(reqs)}
        assert sorted(got) == sorted(ref) == list(range(256))
        for rid in ref:
            np.testing.assert_array_equal(got[rid].perm, ref[rid].perm)
            np.testing.assert_array_equal(got[rid].exposure,
                                          ref[rid].exposure)
            assert got[rid].utility == ref[rid].utility
            assert got[rid].compliant == ref[rid].compliant
            assert got[rid].bucket == ref[rid].bucket


def test_async_stream_preserves_no_recompile_contract():
    reqs, predictor = _mixed_stream(128)
    eng = _engine(2, predictor=predictor)
    eng.warmup(reqs)
    eng.serve_stream(reqs)
    assert eng.metrics.compiles_post_warmup == 0
    sizes = eng.jit_cache_sizes()
    assert sizes and all(v == 1 for v in sizes.values()), sizes


# ---------------------------------------------------------------------------
# Futures: association, ordering, callbacks
# ---------------------------------------------------------------------------


def test_futures_resolve_to_their_own_request():
    """Every future resolves to a result carrying its own rid, and the
    payload matches what the sync engine computes for that rid."""
    reqs, predictor = _mixed_stream(64)
    ref = {r.rid: r
           for r in _engine(0, predictor=predictor).serve_stream(reqs)}
    eng = _engine(2, predictor=predictor)
    eng.warmup(reqs)
    futures = [eng.submit_future(r) for r in reqs]
    eng.drain()
    assert all(f.done() for f in futures)
    for req, fut in zip(reqs, futures):
        res = fut.result(timeout=5.0)
        assert fut.rid == req.rid == res.rid
        np.testing.assert_array_equal(res.perm, ref[req.rid].perm)
        assert res.bucket == fut.bucket_name


def test_futures_within_bucket_resolve_in_dispatch_order():
    """One bucket, several capacity flushes: completion callbacks fire
    batch by batch in dispatch order (the single completion worker
    retires FIFO)."""
    eng = ServingEngine(max_batch=4, max_wait_ms=1e9, pipeline_depth=2)
    order = []
    futures = []
    for i in range(12):
        fut = eng.submit_future(_tiny_request(i))
        fut.add_done_callback(lambda f: order.append(f.rid))
        futures.append(fut)
    eng.drain()
    assert order == list(range(12))


def test_future_result_blocks_until_drain_releases():
    eng = ServingEngine(max_batch=4, max_wait_ms=1e9, pipeline_depth=2)
    fut = eng.submit_future(_tiny_request(0))
    assert not fut.done()                       # queued, not even flushed
    with pytest.raises(TimeoutError):
        fut.result(timeout=0.01)
    eng.drain()
    assert fut.result(timeout=5.0).rid == 0


def test_callback_after_done_fires_immediately():
    eng = ServingEngine(max_batch=1, max_wait_ms=1e9, pipeline_depth=1)
    fut = eng.submit_future(_tiny_request(0))   # max_batch=1: flushes now
    eng.drain()
    fired = []
    fut.add_done_callback(lambda f: fired.append(f.rid))
    assert fired == [0]


# ---------------------------------------------------------------------------
# Graceful drain / shutdown with in-flight batches
# ---------------------------------------------------------------------------


def test_drain_retires_all_inflight_batches():
    """Submit enough for several capacity flushes to be in flight, then
    drain: every result must come back exactly once."""
    eng = ServingEngine(max_batch=4, max_wait_ms=1e9, pipeline_depth=2)
    collected = []
    for i in range(19):                         # 4 full flushes + 3 queued
        collected += eng.submit(_tiny_request(i))
    collected += eng.drain()
    assert sorted(r.rid for r in collected) == list(range(19))
    assert eng.metrics.capacity_flushes == 4
    assert eng.metrics.drain_flushes == 1


def test_close_is_graceful_and_idempotent():
    with ServingEngine(max_batch=4, max_wait_ms=1e9,
                       pipeline_depth=2) as eng:
        futures = [eng.submit_future(_tiny_request(i)) for i in range(8)]
    # context exit closed the engine: in-flight batches were retired
    # (two capacity flushes cover all 8 requests; nothing was queued).
    assert all(f.done() for f in futures)
    eng.close()                                 # second close: no-op
    with pytest.raises(RuntimeError):
        eng._pipeline.submit(None)              # closed pipeline rejects


def test_engine_reusable_after_drain():
    eng = ServingEngine(max_batch=4, max_wait_ms=1e9, pipeline_depth=2)
    first = [eng.submit(_tiny_request(i)) for i in range(4)]
    out1 = sum(first, []) + eng.drain()
    out2 = []
    for i in range(4, 8):
        out2 += eng.submit(_tiny_request(i))
    out2 += eng.drain()
    assert sorted(r.rid for r in out1) == [0, 1, 2, 3]
    assert sorted(r.rid for r in out2) == [4, 5, 6, 7]


def test_retire_error_fails_futures_and_surfaces_on_flush():
    boom = RuntimeError("retire exploded")

    def bad_materialize(pending):
        raise boom

    bucket = bucket_for(m1=64, m2=8, K=2, tag="_lam", batch=4)
    ring = StagingRing(bucket, d_cov=None, depth=1)
    staged = ring.acquire()
    pipe = ExecutionPipeline(depth=1)
    from repro.serving.pipeline import PendingBatch, RankFuture
    fut = RankFuture(0, "b")
    pipe.submit(PendingBatch(bucket=bucket, entries=[], futures=[fut],
                             out=None, staged=staged, ring=ring,
                             t_launch=0.0, trigger="drain",
                             materialize=bad_materialize, build=None))
    with pytest.raises(RuntimeError, match="retire exploded"):
        pipe.flush()
    with pytest.raises(RuntimeError, match="retire exploded"):
        fut.result(timeout=5.0)
    # the failed batch's staging buffers were recycled, not leaked —
    # acquire() would deadlock otherwise (ring depth is 1).
    assert ring.acquire() is staged
    pipe.close()


# ---------------------------------------------------------------------------
# Staging ring: backpressure + buffer safety
# ---------------------------------------------------------------------------


def test_staging_ring_blocks_when_exhausted_and_recycles():
    bucket = bucket_for(m1=64, m2=8, K=2, tag="_lam", batch=4)
    ring = StagingRing(bucket, d_cov=None, depth=2)
    b1, b2 = ring.acquire(), ring.acquire()
    assert b1 is not b2
    grabbed = []
    t = threading.Thread(target=lambda: grabbed.append(ring.acquire()))
    t.start()
    t.join(timeout=0.05)
    assert t.is_alive() and not grabbed         # exhausted: acquire blocks
    ring.release(b1)
    t.join(timeout=5.0)
    assert grabbed == [b1]                      # recycled, not reallocated


def test_staging_buffers_are_not_rewritten_while_in_flight():
    """Two consecutive flushes of one bucket with depth 2 must use
    distinct staging buffers (rewriting the first would race its
    in-flight transfer)."""
    seen = []
    orig_materialize = ServingEngine._materialize_batch

    def spy(self, pending):
        seen.append(id(pending.staged["u"]))
        return orig_materialize(self, pending)

    eng = ServingEngine(max_batch=2, max_wait_ms=1e9, pipeline_depth=2)
    eng._materialize_batch = spy.__get__(eng)
    for i in range(8):                          # 4 back-to-back flushes
        eng.submit(_tiny_request(i))
    eng.drain()
    assert len(seen) == 4
    assert len(set(seen[:2])) == 2              # adjacent flushes differ
    assert len(set(seen)) <= eng.pipeline_depth + 2   # bounded ring: recycled
