"""Async double-buffered pipeline: sync/async result equivalence,
future-to-request association, backpressure, graceful shutdown, and
fault injection under deadline pressure.

The pipeline must be a pure scheduling change: for any stream, the
pipelined engine (pipeline_depth >= 1) returns bitwise the same
perm/utility/exposure/compliance per rid as the synchronous engine
(pipeline_depth=0), differing only in when results materialize. The
fault-injection layer (FaultyExecutor) proves the lifetime invariants
survive injected per-flush delays and failures: drain/shutdown never
deadlocks with mid-flight sheds, every RankFuture resolves exactly
once (served, degraded, shed, or failed), and admission at zero load
is non-interfering (bitwise-identical served results).
"""

import threading
import time

import numpy as np
import pytest

from conftest import FrozenClock

from repro.core.constraints import dcg_discount
from repro.core.predictors import KNNLambdaPredictor, MeanLambdaPredictor
from repro.serving import (
    AdmissionController,
    ExecutionPipeline,
    RankRequest,
    RefreshLane,
    Scenario,
    ServingEngine,
    Shed,
    StagingRing,
    bucket_for,
    make_stream,
)


def _tiny_request(rid, m1=64, m2=8, K=2):
    rng = np.random.default_rng(rid)
    return RankRequest(
        rid=rid, u=rng.uniform(1, 5, m1).astype(np.float32),
        a=(rng.random((K, m1)) < 0.3).astype(np.float32),
        b=np.zeros(K, np.float32), m2=m2,
        lam=np.zeros(K, np.float32),
        gamma=np.asarray(dcg_discount(m2), np.float32))


def _mixed_stream(n=256, seed=4, d=12, K=5):
    """>= 2 archs, >= 3 geometries, predictor + raw-lam paths mixed."""
    rng = np.random.default_rng(seed)
    knn = KNNLambdaPredictor.fit(
        rng.normal(size=(64, d)).astype(np.float32),
        np.abs(rng.normal(size=(64, K))).astype(np.float32), k=5)
    mix = (
        Scenario("feed", m1=500, m2=50, K=K, weight=3.0, tag="knn", d_cov=d),
        Scenario("strip", m1=1000, m2=20, K=3, weight=2.0),
        Scenario("notif", m1=120, m2=8, K=3, weight=1.0),
    )
    return make_stream(mix, n_requests=n, seed=seed), ("knn", knn, d)


def _engine(depth, max_batch=16, max_wait_ms=2.0, predictor=None):
    eng = ServingEngine(max_batch=max_batch, max_wait_ms=max_wait_ms,
                        pipeline_depth=depth)
    if predictor is not None:
        tag, pred, d = predictor
        eng.register_predictor(tag, pred, d_cov=d)
    return eng


# ---------------------------------------------------------------------------
# Sync/async equivalence on a mixed 256-request stream
# ---------------------------------------------------------------------------


def test_sync_async_equivalence_mixed_256_stream():
    reqs, predictor = _mixed_stream(256)
    ref = {r.rid: r
           for r in _engine(0, predictor=predictor).serve_stream(reqs)}
    for depth in (1, 2, 4):
        got = {r.rid: r
               for r in _engine(depth, predictor=predictor).serve_stream(reqs)}
        assert sorted(got) == sorted(ref) == list(range(256))
        for rid in ref:
            np.testing.assert_array_equal(got[rid].perm, ref[rid].perm)
            np.testing.assert_array_equal(got[rid].exposure,
                                          ref[rid].exposure)
            assert got[rid].utility == ref[rid].utility
            assert got[rid].compliant == ref[rid].compliant
            assert got[rid].bucket == ref[rid].bucket


def test_async_stream_preserves_no_recompile_contract():
    reqs, predictor = _mixed_stream(128)
    eng = _engine(2, predictor=predictor)
    eng.warmup(reqs)
    eng.serve_stream(reqs)
    assert eng.metrics.compiles_post_warmup == 0
    sizes = eng.jit_cache_sizes()
    assert sizes and all(v == 1 for v in sizes.values()), sizes


# ---------------------------------------------------------------------------
# Futures: association, ordering, callbacks
# ---------------------------------------------------------------------------


def test_futures_resolve_to_their_own_request():
    """Every future resolves to a result carrying its own rid, and the
    payload matches what the sync engine computes for that rid."""
    reqs, predictor = _mixed_stream(64)
    ref = {r.rid: r
           for r in _engine(0, predictor=predictor).serve_stream(reqs)}
    eng = _engine(2, predictor=predictor)
    eng.warmup(reqs)
    futures = [eng.submit_future(r) for r in reqs]
    eng.drain()
    assert all(f.done() for f in futures)
    for req, fut in zip(reqs, futures):
        res = fut.result(timeout=5.0)
        assert fut.rid == req.rid == res.rid
        np.testing.assert_array_equal(res.perm, ref[req.rid].perm)
        assert res.bucket == fut.bucket_name


def test_futures_within_bucket_resolve_in_dispatch_order():
    """One bucket, several capacity flushes: completion callbacks fire
    batch by batch in dispatch order (the single completion worker
    retires FIFO)."""
    eng = ServingEngine(max_batch=4, max_wait_ms=1e9, pipeline_depth=2)
    order = []
    futures = []
    for i in range(12):
        fut = eng.submit_future(_tiny_request(i))
        fut.add_done_callback(lambda f: order.append(f.rid))
        futures.append(fut)
    eng.drain()
    assert order == list(range(12))


def test_future_result_blocks_until_drain_releases():
    eng = ServingEngine(max_batch=4, max_wait_ms=1e9, pipeline_depth=2)
    fut = eng.submit_future(_tiny_request(0))
    assert not fut.done()                       # queued, not even flushed
    with pytest.raises(TimeoutError):
        fut.result(timeout=0.01)
    eng.drain()
    assert fut.result(timeout=5.0).rid == 0


def test_callback_after_done_fires_immediately():
    eng = ServingEngine(max_batch=1, max_wait_ms=1e9, pipeline_depth=1)
    fut = eng.submit_future(_tiny_request(0))   # max_batch=1: flushes now
    eng.drain()
    fired = []
    fut.add_done_callback(lambda f: fired.append(f.rid))
    assert fired == [0]


# ---------------------------------------------------------------------------
# Graceful drain / shutdown with in-flight batches
# ---------------------------------------------------------------------------


def test_drain_retires_all_inflight_batches():
    """Submit enough for several capacity flushes to be in flight, then
    drain: every result must come back exactly once."""
    eng = ServingEngine(max_batch=4, max_wait_ms=1e9, pipeline_depth=2)
    collected = []
    for i in range(19):                         # 4 full flushes + 3 queued
        collected += eng.submit(_tiny_request(i))
    collected += eng.drain()
    assert sorted(r.rid for r in collected) == list(range(19))
    assert eng.metrics.capacity_flushes == 4
    assert eng.metrics.drain_flushes == 1


def test_close_is_graceful_and_idempotent():
    with ServingEngine(max_batch=4, max_wait_ms=1e9,
                       pipeline_depth=2) as eng:
        futures = [eng.submit_future(_tiny_request(i)) for i in range(8)]
    # context exit closed the engine: in-flight batches were retired
    # (two capacity flushes cover all 8 requests; nothing was queued).
    assert all(f.done() for f in futures)
    eng.close()                                 # second close: no-op
    with pytest.raises(RuntimeError):
        eng._pipeline.submit(None)              # closed pipeline rejects


def test_engine_reusable_after_drain():
    eng = ServingEngine(max_batch=4, max_wait_ms=1e9, pipeline_depth=2)
    first = [eng.submit(_tiny_request(i)) for i in range(4)]
    out1 = sum(first, []) + eng.drain()
    out2 = []
    for i in range(4, 8):
        out2 += eng.submit(_tiny_request(i))
    out2 += eng.drain()
    assert sorted(r.rid for r in out1) == [0, 1, 2, 3]
    assert sorted(r.rid for r in out2) == [4, 5, 6, 7]


def test_retire_error_fails_futures_and_surfaces_on_flush():
    boom = RuntimeError("retire exploded")

    def bad_materialize(pending):
        raise boom

    bucket = bucket_for(m1=64, m2=8, K=2, tag="_lam", batch=4)
    ring = StagingRing(bucket, d_cov=None, depth=1)
    staged = ring.acquire()
    pipe = ExecutionPipeline(depth=1)
    from repro.serving.pipeline import PendingBatch, RankFuture
    fut = RankFuture(0, "b")
    pipe.submit(PendingBatch(bucket=bucket, entries=[], futures=[fut],
                             out=None, staged=staged, ring=ring,
                             t_launch=0.0, trigger="drain",
                             materialize=bad_materialize, build=None))
    with pytest.raises(RuntimeError, match="retire exploded"):
        pipe.flush()
    with pytest.raises(RuntimeError, match="retire exploded"):
        fut.result(timeout=5.0)
    # the failed batch's staging buffers were recycled, not leaked —
    # acquire() would deadlock otherwise (ring depth is 1).
    assert ring.acquire() is staged
    pipe.close()


# ---------------------------------------------------------------------------
# Staging ring: backpressure + buffer safety
# ---------------------------------------------------------------------------


def test_staging_ring_blocks_when_exhausted_and_recycles():
    bucket = bucket_for(m1=64, m2=8, K=2, tag="_lam", batch=4)
    ring = StagingRing(bucket, d_cov=None, depth=2)
    b1, b2 = ring.acquire(), ring.acquire()
    assert b1 is not b2
    grabbed = []
    t = threading.Thread(target=lambda: grabbed.append(ring.acquire()))
    t.start()
    t.join(timeout=0.05)
    assert t.is_alive() and not grabbed         # exhausted: acquire blocks
    ring.release(b1)
    t.join(timeout=5.0)
    assert grabbed == [b1]                      # recycled, not reallocated


# ---------------------------------------------------------------------------
# Fault injection under deadline pressure (admission + pipeline lifetimes)
# ---------------------------------------------------------------------------


class FaultyExecutor:
    """Wraps one bucket executable, injecting a fixed per-flush delay
    and/or a failure on selected flush indices (counted per bucket,
    post-wrap). The delay sits between the engine's t_launch stamp and
    the device call, so it inflates the observed service time exactly
    like a slow device would — which is what drives the admission
    controller's EWMAs up under injected pressure."""

    def __init__(self, fn, *, delay_s=0.0, fail_on=()):
        self.fn = fn
        self.delay_s = float(delay_s)
        self.fail_on = set(fail_on)
        self.calls = 0

    def __call__(self, *args):
        i = self.calls
        self.calls += 1
        if self.delay_s:
            time.sleep(self.delay_s)
        if i in self.fail_on:
            raise RuntimeError(f"injected fault at flush {i}")
        return self.fn(*args)


def _inject_faults(eng, **kw):
    """Wrap every warmed bucket executable of `eng` with FaultyExecutor."""
    wrapped = {}
    for b, fn in list(eng._exec.items()):
        wrapped[b] = eng._exec[b] = FaultyExecutor(fn, **kw)
    return wrapped


def test_injected_dispatch_failure_fails_futures_and_recycles_ring():
    """A flush whose dispatch raises must fail that batch's futures
    (each still resolves exactly once, as an error) and recycle its
    staging buffers; the engine keeps serving afterwards."""
    reqs = [_tiny_request(i) for i in range(12)]
    eng = ServingEngine(max_batch=4, max_wait_ms=1e9, pipeline_depth=1)
    eng.warmup(reqs)
    _inject_faults(eng, fail_on={0})            # first live flush explodes
    futures = [eng.submit_future(r) for r in reqs[:3]]
    with pytest.raises(RuntimeError, match="injected fault"):
        eng.submit_future(reqs[3])              # capacity flush -> boom
    for fut in futures:
        assert fut.done()
        with pytest.raises(RuntimeError, match="injected fault"):
            fut.result(timeout=1.0)
    # flush 1+ succeeds: the failed flush leaked nothing
    out = [eng.submit(r) for r in reqs[4:]]
    drained = sum(out, []) + eng.drain()
    assert sorted(r.rid for r in drained) == list(range(4, 12))
    bucket = eng.bucket_of(reqs[0])
    ring = eng._rings[bucket]
    assert ring._free.qsize() == eng.pipeline_depth + 2   # all recycled
    eng.close()


def test_injected_delays_with_midflight_sheds_never_deadlock():
    """Slow flushes in flight + sheds arriving on top: drain completes,
    every future resolves exactly once (served or shed), and the
    served/shed split is exact."""
    eng = ServingEngine(max_batch=4, max_wait_ms=2.0, pipeline_depth=2,
                        admission=True)
    reqs = [_tiny_request(i) for i in range(16)]
    eng.warmup(reqs)
    _inject_faults(eng, delay_s=0.02)           # every flush 20 ms slow
    fired = {r.rid: 0 for r in reqs}
    futures = []
    for r in reqs[:8]:                          # generous budget: admitted
        r.budget_s = 10.0
        fut = eng.submit_future(r)
        fut.add_done_callback(lambda f: fired.__setitem__(
            f.rid, fired[f.rid] + 1))
        futures.append(fut)
    for r in reqs[8:]:                          # impossible budget: every
        r.budget_s = 1e-4                       # rung predicted to miss
        fut = eng.submit_future(r)              # (max_wait alone exceeds it)
        fut.add_done_callback(lambda f: fired.__setitem__(
            f.rid, fired[f.rid] + 1))
        futures.append(fut)
    drained = []
    t = threading.Thread(target=lambda: drained.extend(eng.drain()))
    t.start()
    t.join(timeout=30.0)
    assert not t.is_alive()                     # drain never deadlocks
    assert all(f.done() for f in futures)
    assert all(n == 1 for n in fired.values())  # exactly-once resolution
    served = [x for x in drained if not isinstance(x, Shed)]
    sheds = [x for x in drained if isinstance(x, Shed)]
    assert sorted(x.rid for x in served) == list(range(8))
    assert sorted(x.rid for x in sheds) == list(range(8, 16))
    assert eng.metrics.sheds == 8 and eng.metrics.results == 8
    # the shed futures resolved to the same typed results the drain saw
    for fut, shed in zip(futures[8:], sorted(sheds, key=lambda s: s.rid)):
        assert fut.result(timeout=1.0) is shed
    eng.close()


def test_admission_noninterference_at_zero_load():
    """With headroom to spare, admission must be a no-op: served
    results are bitwise identical to the admission-disabled engine,
    with zero sheds and zero degrades."""
    rng = np.random.default_rng(7)
    d, K = 8, 3
    knn = KNNLambdaPredictor.fit(
        rng.normal(size=(32, d)).astype(np.float32),
        np.abs(rng.normal(size=(32, K))).astype(np.float32), k=3)
    mean = MeanLambdaPredictor.fit(
        np.zeros((4, d), np.float32),
        np.abs(rng.normal(size=(4, K))).astype(np.float32))
    mix = (Scenario("feed", m1=200, m2=16, K=K, weight=2.0,
                    tag="knn", d_cov=d),
           Scenario("notif", m1=120, m2=8, K=K, weight=1.0))
    reqs = make_stream(mix, n_requests=48, seed=8)

    def build(admission):
        eng = ServingEngine(max_batch=8, max_wait_ms=2.0, pipeline_depth=1,
                            admission=admission, default_budget_s=10.0)
        eng.register_predictor("knn", knn, d_cov=d)
        eng.register_predictor("mean", mean, d_cov=d)
        eng.set_degradation_ladder("knn", ["mean"])
        return eng

    ref = {r.rid: r for r in build(None).serve_stream(reqs)}
    eng = build(AdmissionController())
    got = {r.rid: r for r in eng.serve_stream(reqs)}
    assert eng.metrics.sheds == 0 and eng.metrics.degrades == 0
    assert sorted(got) == sorted(ref)
    for rid in ref:
        assert not isinstance(got[rid], Shed)
        np.testing.assert_array_equal(got[rid].perm, ref[rid].perm)
        np.testing.assert_array_equal(got[rid].exposure, ref[rid].exposure)
        assert got[rid].utility == ref[rid].utility
        assert got[rid].compliant == ref[rid].compliant
        assert got[rid].bucket == ref[rid].bucket
        assert got[rid].rung == 0
    eng.close()


def test_staging_buffers_are_not_rewritten_while_in_flight():
    """Two consecutive flushes of one bucket with depth 2 must use
    distinct staging buffers (rewriting the first would race its
    in-flight transfer)."""
    seen = []
    orig_materialize = ServingEngine._materialize_batch

    def spy(self, pending):
        seen.append(id(pending.staged["u"]))
        return orig_materialize(self, pending)

    eng = ServingEngine(max_batch=2, max_wait_ms=1e9, pipeline_depth=2)
    eng._materialize_batch = spy.__get__(eng)
    for i in range(8):                          # 4 back-to-back flushes
        eng.submit(_tiny_request(i))
    eng.drain()
    assert len(seen) == 4
    assert len(set(seen[:2])) == 2              # adjacent flushes differ
    assert len(set(seen)) <= eng.pipeline_depth + 2   # bounded ring: recycled


# ---------------------------------------------------------------------------
# Refresh-lane fault injection: crashes, races, repeated failures
# ---------------------------------------------------------------------------


def _knn_cov_engine(*, depth, max_batch=8, seed=20, admission=None,
                    clock=None, max_wait_ms=1e9):
    """Covariate-stream engine + request list for the refresh fault
    tests (b_frac=0.3 guarantees exposure shortfall, so a healthy
    refresh always has something to publish)."""
    rng = np.random.default_rng(seed)
    d, K = 8, 3
    knn = KNNLambdaPredictor.fit(
        rng.normal(size=(32, d)).astype(np.float32),
        np.abs(rng.normal(size=(32, K))).astype(np.float32), k=5)
    eng = ServingEngine(max_batch=max_batch, max_wait_ms=max_wait_ms,
                        pipeline_depth=depth, admission=admission,
                        clock=clock or time.perf_counter)
    eng.register_predictor("knn", knn, d_cov=d)
    mix = (Scenario("cov", m1=128, m2=8, K=K, tag="knn", d_cov=d,
                    b_frac=0.3),)
    return eng, make_stream(mix, n_requests=24, seed=seed + 1), knn


def test_refresh_crash_mid_swap_leaves_serving_on_last_good():
    """The update rule explodes while a batch is in flight: the refresh
    reports the failure, `refresh_failures` increments, the epoch never
    moves, and every in-flight future resolves to bitwise the result
    the never-refreshed engine computes."""
    eng, reqs, knn = _knn_cov_engine(depth=2, clock=FrozenClock())
    lane = RefreshLane(eng, min_samples=4)
    eng.warmup(reqs)
    eng.serve_stream(reqs[:12], warmup=False)    # telemetry accumulates
    assert lane.pending("knn") == 12

    def boom(tag, X, targets):
        raise RuntimeError("refresh exploded mid-update")

    lane._updated_state = boom
    futures = [eng.submit_future(r) for r in reqs[12:20]]  # batch in flight
    rep = lane.refresh("knn")["knn"]
    assert not rep["swapped"]
    assert rep["reason"].startswith("refused: refresh exploded")
    assert eng.metrics.refresh_failures == 1
    assert eng.predictor_epoch("knn") == 0       # still on last-good
    eng.drain()
    assert all(f.done() for f in futures)

    cold, _, _ = _knn_cov_engine(depth=0, clock=FrozenClock())
    cold.serve_stream(reqs[:12])
    ref = {r.rid: r for r in cold.serve_stream(reqs[12:20], warmup=False)}
    for fut in futures:
        res = fut.result(timeout=5.0)
        assert res.epoch == 0
        np.testing.assert_array_equal(res.perm, ref[res.rid].perm)
        np.testing.assert_array_equal(res.exposure, ref[res.rid].exposure)
        assert res.utility == ref[res.rid].utility
    eng.close()


def test_swap_racing_drain_and_sheds_never_deadlocks():
    """Hot swaps hammering the epoch fence while slow flushes are in
    flight and admission sheds arrive on top: drain completes, every
    future resolves exactly once, and the served/shed split is exact."""
    eng, reqs, knn = _knn_cov_engine(depth=2, max_batch=4, max_wait_ms=2.0,
                                     admission=AdmissionController())
    eng.warmup(reqs)
    _inject_faults(eng, delay_s=0.02)            # every flush 20 ms slow
    from repro.core.predictors import predictor_state
    import jax
    base = jax.device_get(predictor_state(knn))

    stop = threading.Event()
    swap_errors = []

    def swapper():
        i = 0
        while not stop.is_set():
            i += 1
            try:
                eng.swap_predictor("knn", {
                    "X_db": base["X_db"] + np.float32(1e-4 * i),
                    "lam_db": base["lam_db"]})
            except Exception as e:               # noqa: BLE001
                swap_errors.append(e)
            time.sleep(0.002)

    t_swap = threading.Thread(target=swapper)
    t_swap.start()
    fired = {r.rid: 0 for r in reqs[:16]}
    futures = []
    for r in reqs[:8]:                           # generous budget: admitted
        r.budget_s = 10.0
        fut = eng.submit_future(r)
        fut.add_done_callback(lambda f: fired.__setitem__(
            f.rid, fired[f.rid] + 1))
        futures.append(fut)
    for r in reqs[8:16]:                         # impossible budget: shed
        r.budget_s = 1e-4
        fut = eng.submit_future(r)
        fut.add_done_callback(lambda f: fired.__setitem__(
            f.rid, fired[f.rid] + 1))
        futures.append(fut)
    drained = []
    t_drain = threading.Thread(target=lambda: drained.extend(eng.drain()))
    t_drain.start()
    t_drain.join(timeout=30.0)
    stop.set()
    t_swap.join(timeout=5.0)
    assert not t_drain.is_alive()                # drain never deadlocks
    assert not t_swap.is_alive()
    assert not swap_errors
    assert all(f.done() for f in futures)
    assert all(n == 1 for n in fired.values())   # exactly-once resolution
    served = [x for x in drained if not isinstance(x, Shed)]
    sheds = [x for x in drained if isinstance(x, Shed)]
    assert sorted(x.rid for x in served) == [r.rid for r in reqs[:8]]
    assert sorted(x.rid for x in sheds) == [r.rid for r in reqs[8:16]]
    # no generation left pinned once everything materialized
    assert eng._inflight_gens == {}
    eng.close()


def test_repeated_failed_refreshes_increment_counter_without_wedging():
    """Poisoned generation after poisoned generation: the engine
    refuses each one, `refresh_failures` counts them all, the lane
    never wedges — and the next HEALTHY refresh still swaps."""
    eng, reqs, knn = _knn_cov_engine(depth=1, max_batch=4,
                                     clock=FrozenClock())
    lane = RefreshLane(eng, min_samples=2)
    eng.warmup(reqs)
    orig = lane._updated_state

    def poisoned(tag, X, targets):
        state = orig(tag, X, targets)
        return {k: np.full_like(np.asarray(v), np.nan)
                for k, v in state.items()}

    lane._updated_state = poisoned
    for i in range(3):
        eng.serve_stream(reqs[4 * i:4 * (i + 1)], warmup=False)
        rep = lane.refresh("knn")["knn"]
        assert not rep["swapped"] and "poisoned" in rep["reason"]
        assert eng.metrics.refresh_failures == i + 1
        assert eng.predictor_epoch("knn") == 0
    lane._updated_state = orig                   # lane recovers
    eng.serve_stream(reqs[12:16], warmup=False)
    rep = lane.refresh("knn")["knn"]
    assert rep["swapped"] and rep["epoch"] == 1
    out = eng.serve_stream(reqs[16:], warmup=False)
    assert sorted(r.rid for r in out) == [r.rid for r in reqs[16:]]
    assert all(r.epoch == 1 for r in out)
    assert eng.metrics.refresh_failures == 3
    assert eng.metrics.compiles_post_warmup == 0
    eng.close()


def test_background_lane_crash_is_contained():
    """A crash inside the background loop itself (a lane bug, not a
    refused swap) counts a failure and the loop keeps running — serving
    is never taken down by its refresh lane."""
    eng, reqs, _ = _knn_cov_engine(depth=0, clock=FrozenClock())
    lane = RefreshLane(eng)
    crashes = []

    def crashing_refresh(tag=None):
        crashes.append(1)
        raise RuntimeError("lane bug")

    lane.refresh = crashing_refresh
    lane.start(interval_s=0.001)
    deadline = time.monotonic() + 5.0
    while len(crashes) < 2 and time.monotonic() < deadline:
        time.sleep(0.005)
    lane.stop()
    assert len(crashes) >= 2                     # crashed, kept looping
    assert eng.metrics.refresh_failures >= 2
    out = eng.serve_stream(reqs[:4])             # engine unharmed
    assert sorted(r.rid for r in out) == [r.rid for r in reqs[:4]]
    eng.close()
