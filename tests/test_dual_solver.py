"""Dual solver vs the exact constrained brute-force oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.assignment import brute_force_constrained
from repro.core.constraints import ConstraintSet, dcg_discount, make_constraints
from repro.core.dual_solver import serve_rank, solve_dual, solve_dual_batch


def _instance(seed, m1=8, m2=4, K=2):
    """Small feasible constrained-ranking instance."""
    rng = np.random.default_rng(seed)
    u = rng.uniform(1, 5, size=m1).astype(np.float32)
    gamma = np.asarray(dcg_discount(m2))
    a = (rng.uniform(size=(K, m1)) < 0.4).astype(np.float32)
    # threshold: half of what the best single placement could achieve
    b = np.asarray([0.5 * gamma[0] * max(a[k].max(), 0.1) for k in range(K)],
                   np.float32)
    return u, a, b, gamma


@pytest.mark.parametrize("seed", range(6))
def test_dual_solution_near_oracle(seed):
    u, a, b, gamma = _instance(seed)
    m2 = len(gamma)
    sol = solve_dual(jnp.asarray(u), ConstraintSet(a=jnp.asarray(a), b=jnp.asarray(b)),
                     jnp.asarray(gamma), m2=m2, num_iters=300)
    A = np.stack([np.outer(a[k], gamma) for k in range(len(b))])
    U = np.outer(u, gamma)
    perm_bf, v_bf = brute_force_constrained(U, A, b, np.ones(len(b)))
    assert perm_bf is not None, "instance should be feasible"
    # compliant and within 2% of the exact constrained optimum
    assert bool(sol.compliant)
    assert float(sol.primal_value) >= v_bf - 0.02 * abs(v_bf)
    # dual value upper-bounds the constrained optimum (weak duality)
    assert float(sol.dual_value) >= v_bf - 1e-3


@pytest.mark.parametrize("seed", range(3))
def test_duality_gap_nonnegative_and_small(seed):
    u, a, b, gamma = _instance(seed, m1=20, m2=8, K=3)
    sol = solve_dual(jnp.asarray(u), ConstraintSet(a=jnp.asarray(a), b=jnp.asarray(b)),
                     jnp.asarray(gamma), m2=8, num_iters=400)
    assert float(sol.gap) >= -1e-3
    assert float(sol.gap) <= 0.1 * abs(float(sol.dual_value)) + 0.5


def test_batch_matches_single():
    u0, a0, b0, gamma = _instance(0)
    u1, a1, _, _ = _instance(1)
    ub = jnp.stack([jnp.asarray(u0), jnp.asarray(u1)])
    ab = jnp.stack([jnp.asarray(a0), jnp.asarray(a1)])
    sol_b = solve_dual_batch(ub, ab, jnp.asarray(b0), jnp.asarray(gamma),
                             m2=4, num_iters=150)
    sol_0 = solve_dual(jnp.asarray(u0),
                       ConstraintSet(a=jnp.asarray(a0), b=jnp.asarray(b0)),
                       jnp.asarray(gamma), m2=4, num_iters=150)
    np.testing.assert_allclose(sol_b.lam[0], sol_0.lam, rtol=1e-5, atol=1e-6)
    assert sol_b.lam.shape == (2, len(b0))


def test_scale_invariance():
    """lambda scales linearly with utility scale (the normalized solver)."""
    u, a, b, gamma = _instance(3)
    cs = ConstraintSet(a=jnp.asarray(a), b=jnp.asarray(b))
    sol1 = solve_dual(jnp.asarray(u), cs, jnp.asarray(gamma), m2=4, num_iters=200)
    sol2 = solve_dual(jnp.asarray(u) * 100.0, cs, jnp.asarray(gamma), m2=4,
                      num_iters=200)
    np.testing.assert_allclose(sol2.lam, sol1.lam * 100.0, rtol=1e-4, atol=1e-4)


def test_infeasible_flagged_not_crashed():
    u = jnp.asarray(np.random.default_rng(0).uniform(1, 5, 6), jnp.float32)
    a = jnp.zeros((1, 6))          # constraint attribute absent everywhere
    b = jnp.asarray([1.0])         # ... but exposure >= 1 required
    gamma = dcg_discount(3)
    sol = solve_dual(u, ConstraintSet(a=a, b=b), gamma, m2=3, num_iters=100)
    assert not bool(sol.compliant)
    assert np.isfinite(float(sol.dual_value))


def test_serve_rank_hot_path():
    u, a, b, gamma = _instance(2)
    lam = jnp.asarray([0.5, 0.2])
    perm, util = serve_rank(jnp.asarray(u), jnp.asarray(a), lam,
                            jnp.asarray(gamma), m2=4)
    assert perm.shape == (4,)
    s = np.asarray(u) + (1 + 1e-4) * (np.asarray(lam) @ np.asarray(a))
    np.testing.assert_array_equal(np.asarray(perm), np.argsort(-s)[:4])
