"""LM family: shapes, numerics, decode==forward consistency, training."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.batches import make_lm_batch
from repro.models.transformer import LMConfig, TransformerLM
from repro.optim import adam_init

CFG = LMConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_head=8,
               d_ff=64, vocab=64, dtype=jnp.float32, param_dtype=jnp.float32,
               remat="none", dense_attn_threshold=4096)


@pytest.fixture(scope="module")
def model_and_params():
    model = TransformerLM(CFG)
    return model, model.init(jax.random.key(0))


def test_forward_shapes_and_finite(model_and_params):
    model, params = model_and_params
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, CFG.vocab)
    logits, aux = model.forward(params, tokens)
    assert logits.shape == (2, 16, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_causality(model_and_params):
    """Changing a future token must not change past logits."""
    model, params = model_and_params
    t1 = jax.random.randint(jax.random.key(2), (1, 12), 0, CFG.vocab)
    t2 = t1.at[0, 8].set((t1[0, 8] + 1) % CFG.vocab)
    l1, _ = model.forward(params, t1)
    l2, _ = model.forward(params, t2)
    np.testing.assert_allclose(l1[0, :8], l2[0, :8], rtol=1e-5, atol=1e-5)
    assert not np.allclose(l1[0, 8:], l2[0, 8:], atol=1e-6)


def test_chunked_attention_matches_dense():
    cfg = LMConfig(**{**CFG.__dict__, "dense_attn_threshold": 0,
                      "attn_chunk_q": 4, "attn_chunk_kv": 4})
    cfg_dense = CFG
    model_c, model_d = TransformerLM(cfg), TransformerLM(cfg_dense)
    params = model_d.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(3), (2, 16), 0, CFG.vocab)
    lc, _ = model_c.forward(params, tokens)
    ld, _ = model_d.forward(params, tokens)
    np.testing.assert_allclose(lc, ld, rtol=2e-4, atol=2e-4)


def test_decode_matches_forward(model_and_params):
    """prefill + decode_step token-by-token == full forward logits."""
    model, params = model_and_params
    B, S = 2, 10
    tokens = jax.random.randint(jax.random.key(4), (B, S), 0, CFG.vocab)
    full_logits, _ = model.forward(params, tokens)

    prompt = tokens[:, :4]
    cache_seed = model.make_cache(B, S)
    cache, logits_p = model.prefill(params, prompt)
    # copy prefill cache into the static decode cache
    cache_full = {
        "k": cache_seed["k"].at[:, :, :4].set(cache["k"]),
        "v": cache_seed["v"].at[:, :, :4].set(cache["v"]),
    }
    np.testing.assert_allclose(logits_p, full_logits[:, 3], rtol=2e-4, atol=2e-4)
    for pos in range(4, S):
        logits_d, cache_full = model.decode_step(
            params, cache_full, tokens[:, pos], jnp.asarray(pos))
        np.testing.assert_allclose(
            logits_d, full_logits[:, pos], rtol=2e-4, atol=2e-4,
            err_msg=f"pos {pos}")


def test_train_loss_decreases(model_and_params):
    model, params = model_and_params
    opt = adam_init(params)
    batch = make_lm_batch(jax.random.key(5), batch=8, seq=32, vocab=CFG.vocab)

    @jax.jit
    def step(p, o, b):
        return model.train_step(p, o, b, lr=1e-2)

    losses = []
    for i in range(30):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::10]
    assert np.isfinite(losses).all()


def test_moe_forward_and_train():
    cfg = LMConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_head=8,
                   d_ff=64, vocab=64, moe=True, n_experts=4, top_k=2,
                   d_ff_moe=32, shared_expert=True,
                   dtype=jnp.float32, param_dtype=jnp.float32, remat="none",
                   dense_attn_threshold=4096)
    model = TransformerLM(cfg)
    params = model.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
    logits, aux = model.forward(params, tokens)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert float(aux) > 0  # load-balance loss present
    opt = adam_init(params)
    batch = make_lm_batch(jax.random.key(2), batch=4, seq=16, vocab=cfg.vocab)
    p2, _, metrics = model.train_step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    diff = jax.tree.reduce(
        lambda acc, x: acc + float(jnp.sum(jnp.abs(x))),
        jax.tree.map(lambda a, b: a - b, p2, params), 0.0)
    assert diff > 0


def test_param_count_formula(model_and_params):
    model, params = model_and_params
    n_actual = sum(x.size for x in jax.tree.leaves(params))
    assert n_actual == CFG.n_params
