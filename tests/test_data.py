"""Synthetic data generators: statistics, determinism, learnability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.data.batches import make_csr_graph, make_lm_batch, make_seqrec_batch
from repro.data.synthetic import (
    YOW_TOPIC_RATE,
    make_interactions,
    make_movielens_corpus,
    make_yow_corpus,
    movielens_constraints,
    yow_constraints,
)
from repro.core.constraints import dcg_discount

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


def test_interactions_ratings_in_range():
    d = make_interactions(jax.random.key(0), n_users=50, n_items=60,
                          n_obs=2000)
    r = np.asarray(d.rating)
    assert r.min() >= 1 and r.max() <= 5
    assert len(np.unique(r)) >= 3   # not degenerate


def test_generators_deterministic():
    a = make_interactions(jax.random.key(5), n_users=20, n_items=30, n_obs=100)
    b = make_interactions(jax.random.key(5), n_users=20, n_items=30, n_obs=100)
    np.testing.assert_array_equal(a.rating, b.rating)


def test_movielens_topic_rates():
    c = make_movielens_corpus(jax.random.key(1), 20000)
    rates = np.asarray(c.topics).mean(axis=1)
    np.testing.assert_allclose(rates, 0.05, atol=0.01)
    years = np.asarray(c.extra[0]) * 100 + 1990
    assert years.min() >= 1950 and years.max() < 2020


def test_yow_topic_rates_match_table_1b():
    c = make_yow_corpus(jax.random.key(2), 50000)
    rates = np.asarray(c.topics).mean(axis=1)
    np.testing.assert_allclose(rates, YOW_TOPIC_RATE, atol=0.01)


@given(st.sampled_from([50, 500, 1000]))
def test_constraint_signs_and_scales(m2):
    gamma = dcg_discount(m2)
    keyc = jax.random.key(3)
    ml = movielens_constraints(make_movielens_corpus(keyc, 3000),
                               jnp.arange(1000), gamma, m2)
    assert ml.a.shape == (5, 1000)
    assert float(ml.b[-1]) == 0.0            # release-year threshold
    yw = yow_constraints(make_yow_corpus(keyc, 3000), jnp.arange(1000),
                         gamma, m2)
    assert yw.a.shape == (8, 1000)
    # <= rows were sign-flipped: their attribute rows are <= 0
    assert float(yw.a[2].max()) <= 0.0        # business is a <= constraint
    assert float(yw.a[0].min()) >= 0.0        # sci&tech is a >= constraint


def test_lm_batch_next_token_structure():
    b = make_lm_batch(jax.random.key(4), batch=4, seq=32, vocab=101)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert int(b["tokens"].max()) < 101


def test_seqrec_batches_within_vocab():
    for kind in ("sasrec", "bert4rec", "mind"):
        b = make_seqrec_batch(jax.random.key(6), batch=4, seq_len=12,
                              n_items=77, n_neg=5, kind=kind, n_mask=3)
        for k, v in b.items():
            assert int(v.max()) < 77, (kind, k)
            assert int(v.min()) >= 0


def test_csr_graph_valid():
    indptr, indices = make_csr_graph(jax.random.key(7), n_nodes=200,
                                     avg_degree=4)
    assert indptr.shape == (201,)
    assert int(indptr[0]) == 0
    assert int(indptr[-1]) == indices.shape[0]
    assert bool(jnp.all(jnp.diff(indptr) >= 1))  # min degree 1
    assert int(indices.max()) < 200
