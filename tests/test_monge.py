"""(Inverse) Monge structure properties (paper Appendix A)."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.monge import is_inverse_monge, is_permuted_inverse_monge, monge_defect

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@given(st.integers(0, 10_000), st.integers(2, 10), st.integers(2, 10))
def test_outer_product_of_sorted_vectors_is_inverse_monge(seed, m, n):
    rng = np.random.default_rng(seed)
    s = np.sort(rng.normal(size=m))[::-1]
    gamma = np.sort(rng.uniform(0.01, 1, size=n))[::-1]
    S = jnp.asarray(np.outer(s, gamma))
    assert bool(is_inverse_monge(S))
    assert float(monge_defect(S)) == 0.0


@given(st.integers(0, 10_000), st.integers(2, 8), st.integers(2, 8))
def test_fixed_discounting_is_permuted_inverse_monge(seed, m, n):
    rng = np.random.default_rng(seed)
    s = rng.normal(size=m)          # arbitrary order
    gamma = np.sort(rng.uniform(0.01, 1, size=n))[::-1]
    S = jnp.asarray(np.outer(s, gamma))
    assert bool(is_permuted_inverse_monge(S))


@given(st.integers(0, 10_000), st.integers(3, 8))
def test_monge_closure_under_nonneg_combination(seed, m):
    """Appendix A: tau*C, C + D, and F = C + alpha_i + beta_j stay
    inverse Monge."""
    rng = np.random.default_rng(seed)

    def rand_monge():
        s = np.sort(rng.normal(size=m))[::-1]
        g = np.sort(rng.uniform(0.01, 1, size=m))[::-1]
        return np.outer(s, g)

    C, D = rand_monge(), rand_monge()
    tau = rng.uniform(0, 5)
    assert bool(is_inverse_monge(jnp.asarray(tau * C)))
    assert bool(is_inverse_monge(jnp.asarray(C + D)))
    alpha = rng.normal(size=m)
    beta = rng.normal(size=m)
    F = C + alpha[:, None] + beta[None, :]
    assert bool(is_inverse_monge(jnp.asarray(F)))


def test_non_monge_detected():
    S = jnp.asarray([[0.0, 1.0], [1.0, 0.0]])  # anti-diagonal: not inv-Monge
    assert not bool(is_inverse_monge(S))
    assert float(monge_defect(S)) > 0
