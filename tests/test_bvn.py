"""Birkhoff-von Neumann decomposition (the paper's primal rounding)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.assignment import perm_to_matrix
from repro.core.bvn import (
    bvn_decompose,
    is_doubly_stochastic,
    sample_ranking,
    sinkhorn_project,
)

settings.register_profile("ci", max_examples=10, deadline=None)
settings.load_profile("ci")


def _random_ds(seed, m):
    rng = np.random.default_rng(seed)
    M = rng.uniform(0.1, 1.0, size=(m, m))
    return np.asarray(sinkhorn_project(jnp.asarray(M), iters=400))


@given(st.integers(0, 500), st.integers(2, 7))
def test_decomposition_reconstructs(seed, m):
    P = _random_ds(seed, m)
    coeffs, perms = bvn_decompose(P)
    assert np.isclose(coeffs.sum(), 1.0, atol=1e-6)
    R = np.zeros((m, m))
    for c, perm in zip(coeffs, perms):
        R += c * np.asarray(perm_to_matrix(jnp.asarray(perm), m))
    np.testing.assert_allclose(R, P, atol=5e-3)
    assert len(coeffs) <= (m - 1) ** 2 + 1


def test_permutation_matrix_is_its_own_decomposition():
    perm = np.asarray([2, 0, 1])
    P = np.asarray(perm_to_matrix(jnp.asarray(perm), 3))
    coeffs, perms = bvn_decompose(P)
    assert len(coeffs) == 1
    np.testing.assert_array_equal(perms[0], perm)


def test_sampling_matches_marginals():
    P = _random_ds(7, 4)
    coeffs, perms = bvn_decompose(P)
    counts = np.zeros((4, 4))
    n = 3000
    for i in range(n):
        perm = np.asarray(sample_ranking(jax.random.key(i), coeffs, perms))
        counts[perm, np.arange(4)] += 1
    np.testing.assert_allclose(counts / n, P, atol=0.05)


def test_rejects_non_ds():
    with pytest.raises(ValueError):
        bvn_decompose(np.ones((3, 3)))


def test_sinkhorn_produces_ds():
    M = np.random.default_rng(0).uniform(0.5, 2.0, size=(6, 6))
    P = sinkhorn_project(jnp.asarray(M), iters=500)
    assert is_doubly_stochastic(P, atol=1e-4)
