"""Checkpoint store + fault-tolerant runner + per-epoch predictor
checkpoints (the serving fleet's restart path): every predictor
family's epoch state must round-trip bitwise — a hot engine and an
engine RESTORED from the epoch checkpoint serve identical results —
and a corrupted newest epoch must be refused in favor of the previous
one, never served half-written."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import FrozenClock

from repro.checkpoint import CheckpointStore
from repro.core.predictors import (
    KNNLambdaPredictor,
    LinearLambdaPredictor,
    MeanLambdaPredictor,
    MLPLambdaPredictor,
    predictor_state,
)
from repro.data.synthetic import DriftSpec
from repro.distributed.runner import FaultTolerantRunner
from repro.serving import RefreshLane, ServingEngine, make_drift_stream


@pytest.fixture
def store(tmp_path):
    return CheckpointStore(str(tmp_path / "ckpt"), keep_last=2)


def _tree():
    return {
        "w": jnp.arange(12.0).reshape(3, 4),
        "nested": {"b16": jnp.ones((2, 2), jnp.bfloat16),
                   "i": jnp.asarray([1, 2, 3], jnp.int32)},
        "lst": [jnp.zeros(2), jnp.full((3,), 7.0)],
    }


def test_roundtrip_preserves_values_and_dtypes(store):
    tree = _tree()
    store.save(5, tree, extra={"next_step": 5})
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    out, extra = store.restore(like)
    assert extra == {"next_step": 5}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_gc_keeps_last_n(store):
    for s in (1, 2, 3, 4):
        store.save(s, {"x": jnp.zeros(1)})
    assert store.steps() == [3, 4]


def test_async_save_then_restore(store):
    tree = _tree()
    store.save_async(9, tree)
    store.wait()
    assert store.latest_step() == 9


def test_atomicity_no_partial_dirs(store, tmp_path):
    store.save(1, _tree())
    names = os.listdir(store.directory)
    assert all(".tmp-" not in n for n in names)


def test_restore_shape_mismatch_raises(store):
    store.save(1, {"x": jnp.zeros((4,))})
    with pytest.raises(ValueError, match="shape"):
        store.restore({"x": jax.ShapeDtypeStruct((5,), jnp.float32)})


def test_runner_recovers_from_failures(store):
    def step_fn(state, batch):
        return {"w": state["w"] + batch}, {"w0": float(state["w"][0])}

    def batch_fn(step):
        return jnp.full((2,), float(step))

    runner = FaultTolerantRunner(store, step_fn, batch_fn, ckpt_every=4,
                                 max_restarts=4, async_ckpt=False)
    fails = {6, 11}
    state, report = runner.run(
        {"w": jnp.zeros(2)}, 16,
        fail_at=lambda s: s in fails and not fails.discard(s))
    assert report.restarts == 2
    # deterministic replay: result identical to a failure-free run
    np.testing.assert_allclose(state["w"], sum(range(16)))


def test_runner_gives_up_after_max_restarts(store):
    def step_fn(state, batch):
        raise RuntimeError("dead device")

    runner = FaultTolerantRunner(store, step_fn, lambda s: None,
                                 max_restarts=2, async_ckpt=False)
    with pytest.raises(RuntimeError, match="dead device"):
        runner.run({"w": jnp.zeros(1)}, 5)


def test_runner_resumes_from_checkpoint(store):
    def step_fn(state, batch):
        return {"w": state["w"] + 1.0}, {}

    runner = FaultTolerantRunner(store, step_fn, lambda s: None,
                                 ckpt_every=5, async_ckpt=False)
    state, _ = runner.run({"w": jnp.zeros(1)}, 10)
    assert float(state["w"][0]) == 10
    # new runner, same store: resumes at step 10, runs 5 more
    state2, report2 = runner.run({"w": jnp.zeros(1)}, 15)
    assert float(state2["w"][0]) == 15
    assert report2.steps_run == 5


# ---------------------------------------------------------------------------
# Per-epoch predictor checkpoints (the fleet restart path)
# ---------------------------------------------------------------------------

TAG = "arch"
D_COV, K = 10, 4


def _fit(family, rng):
    X = rng.normal(size=(48, D_COV)).astype(np.float32)
    lam = np.abs(rng.normal(size=(48, K))).astype(np.float32)
    if family == "knn":
        return KNNLambdaPredictor.fit(X, lam, k=5)
    if family == "linear":
        return LinearLambdaPredictor.fit(jnp.asarray(X), jnp.asarray(lam))
    if family == "mean":
        return MeanLambdaPredictor.fit(X, lam)
    if family == "mlp":
        return MLPLambdaPredictor.fit(X, lam, d_hidden=16, num_steps=30)
    raise ValueError(family)


def _stream(n=32, seed=0):
    return make_drift_stream(DriftSpec(kind="none"), tag=TAG, n_requests=n,
                             m1=96, m2=8, K=K, d_cov=D_COV, b_frac=0.25,
                             seed=seed)


def _engine(pred):
    eng = ServingEngine(max_batch=4, max_wait_ms=1e9, pipeline_depth=0,
                        clock=FrozenClock())
    eng.register_predictor(TAG, pred, d_cov=D_COV)
    return eng


def _assert_same(got, ref):
    np.testing.assert_array_equal(got.perm, ref.perm)
    np.testing.assert_array_equal(got.exposure, ref.exposure)
    assert got.utility == ref.utility and got.epoch == ref.epoch


def _host(state):
    return jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)


@pytest.mark.parametrize("family", ["mean", "knn", "linear", "mlp"])
def test_epoch_state_roundtrip_bitwise(family, store):
    """save_predictor_epoch -> load_predictor_epoch returns every leaf
    bitwise, with and without a `like` template."""
    state = _host(predictor_state(_fit(family, np.random.default_rng(0))))
    store.save_predictor_epoch(TAG, 3, state)
    assert store.predictor_epochs(TAG) == [3]
    for like in (None, state):
        loaded, epoch = store.load_predictor_epoch(TAG, like=like)
        assert epoch == 3
        got, _ = jax.tree_util.tree_flatten(loaded)
        ref, _ = jax.tree_util.tree_flatten(state)
        assert len(got) == len(ref)
        for g, r in zip(got, ref):
            assert np.asarray(g).dtype == np.asarray(r).dtype
            np.testing.assert_array_equal(np.asarray(g), np.asarray(r))


@pytest.mark.parametrize("family", ["mean", "knn", "linear", "mlp"])
def test_restored_engine_serves_epoch_bitwise(family, store):
    """The fleet restart contract, per family: a refresh-published
    epoch checkpointed by the lane, restored into a COLD engine via
    swap_predictor(epoch=...), serves the post-swap stream bitwise
    identically to the hot engine that published it — resuming at
    last-good λ̂, not at epoch 0."""
    rng = np.random.default_rng(1)
    pred = _fit(family, rng)
    reqs = _stream(32, seed=2)
    first, second = reqs[:16], reqs[16:]

    hot = _engine(pred)
    lane = RefreshLane(hot, eta=0.5, min_samples=4, mlp_steps=10,
                       checkpoint=store)
    hot.warmup(reqs)
    hot.serve_stream(first, warmup=False)
    rep = lane.refresh(TAG)[TAG]
    assert rep["swapped"] and rep["checkpointed"] and rep["epoch"] == 1
    assert store.predictor_epochs(TAG) == [1]
    hot_out = hot.serve_stream(second, warmup=False)
    assert all(r.epoch == 1 for r in hot_out)

    cold = _engine(_fit(family, np.random.default_rng(1)))  # same epoch-0 fit
    state, epoch = store.load_predictor_epoch(TAG)
    assert epoch == 1
    assert cold.swap_predictor(TAG, state, epoch=epoch) == 1
    cold.warmup(reqs)
    cold_out = cold.serve_stream(second, warmup=False)
    assert len(cold_out) == len(hot_out)
    for g, r in zip(cold_out, hot_out):
        _assert_same(g, r)


def test_corrupted_newest_epoch_falls_back_to_previous(store):
    state1 = {"lam": np.ones((3, K), np.float32)}
    state2 = {"lam": np.full((3, K), 2.0, np.float32)}
    store.save_predictor_epoch(TAG, 1, state1)
    path2 = store.save_predictor_epoch(TAG, 2, state2)
    with open(os.path.join(path2, "arrays.npz"), "wb") as f:
        f.write(b"not an npz")                  # torn write / disk fault
    loaded, epoch = store.load_predictor_epoch(TAG)
    assert epoch == 1
    np.testing.assert_array_equal(loaded["lam"], state1["lam"])
    # pinning the corrupted epoch explicitly must refuse, not fall back
    with pytest.raises(FileNotFoundError, match="epoch 2"):
        store.load_predictor_epoch(TAG, epoch=2)


def test_nonfinite_epoch_refused(store):
    store.save_predictor_epoch(TAG, 1, {"w": np.ones(4, np.float32)})
    store.save_predictor_epoch(
        TAG, 2, {"w": np.full(4, np.nan, np.float32)})
    _, epoch = store.load_predictor_epoch(TAG)
    assert epoch == 1                           # NaN epoch refused


def test_no_loadable_epoch_raises(store):
    with pytest.raises(FileNotFoundError, match="no predictor checkpoints"):
        store.load_predictor_epoch("nope")
    path = store.save_predictor_epoch(TAG, 1, {"w": np.ones(2, np.float32)})
    os.remove(os.path.join(path, "manifest.json"))
    with pytest.raises(FileNotFoundError, match="no loadable"):
        store.load_predictor_epoch(TAG)


def test_epoch_checkpoints_respect_keep_last(store):
    for e in (1, 2, 3, 4):
        store.save_predictor_epoch(TAG, e, {"w": np.full(2, float(e))})
    assert store.predictor_epochs(TAG) == [3, 4]   # keep_last=2
    _, epoch = store.load_predictor_epoch(TAG)
    assert epoch == 4
