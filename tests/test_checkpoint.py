"""Checkpoint store + fault-tolerant runner."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore
from repro.distributed.runner import FaultTolerantRunner


@pytest.fixture
def store(tmp_path):
    return CheckpointStore(str(tmp_path / "ckpt"), keep_last=2)


def _tree():
    return {
        "w": jnp.arange(12.0).reshape(3, 4),
        "nested": {"b16": jnp.ones((2, 2), jnp.bfloat16),
                   "i": jnp.asarray([1, 2, 3], jnp.int32)},
        "lst": [jnp.zeros(2), jnp.full((3,), 7.0)],
    }


def test_roundtrip_preserves_values_and_dtypes(store):
    tree = _tree()
    store.save(5, tree, extra={"next_step": 5})
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    out, extra = store.restore(like)
    assert extra == {"next_step": 5}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_gc_keeps_last_n(store):
    for s in (1, 2, 3, 4):
        store.save(s, {"x": jnp.zeros(1)})
    assert store.steps() == [3, 4]


def test_async_save_then_restore(store):
    tree = _tree()
    store.save_async(9, tree)
    store.wait()
    assert store.latest_step() == 9


def test_atomicity_no_partial_dirs(store, tmp_path):
    store.save(1, _tree())
    names = os.listdir(store.directory)
    assert all(".tmp-" not in n for n in names)


def test_restore_shape_mismatch_raises(store):
    store.save(1, {"x": jnp.zeros((4,))})
    with pytest.raises(ValueError, match="shape"):
        store.restore({"x": jax.ShapeDtypeStruct((5,), jnp.float32)})


def test_runner_recovers_from_failures(store):
    def step_fn(state, batch):
        return {"w": state["w"] + batch}, {"w0": float(state["w"][0])}

    def batch_fn(step):
        return jnp.full((2,), float(step))

    runner = FaultTolerantRunner(store, step_fn, batch_fn, ckpt_every=4,
                                 max_restarts=4, async_ckpt=False)
    fails = {6, 11}
    state, report = runner.run(
        {"w": jnp.zeros(2)}, 16,
        fail_at=lambda s: s in fails and not fails.discard(s))
    assert report.restarts == 2
    # deterministic replay: result identical to a failure-free run
    np.testing.assert_allclose(state["w"], sum(range(16)))


def test_runner_gives_up_after_max_restarts(store):
    def step_fn(state, batch):
        raise RuntimeError("dead device")

    runner = FaultTolerantRunner(store, step_fn, lambda s: None,
                                 max_restarts=2, async_ckpt=False)
    with pytest.raises(RuntimeError, match="dead device"):
        runner.run({"w": jnp.zeros(1)}, 5)


def test_runner_resumes_from_checkpoint(store):
    def step_fn(state, batch):
        return {"w": state["w"] + 1.0}, {}

    runner = FaultTolerantRunner(store, step_fn, lambda s: None,
                                 ckpt_every=5, async_ckpt=False)
    state, _ = runner.run({"w": jnp.zeros(1)}, 10)
    assert float(state["w"][0]) == 10
    # new runner, same store: resumes at step 10, runs 5 more
    state2, report2 = runner.run({"w": jnp.zeros(1)}, 15)
    assert float(state2["w"][0]) == 15
    assert report2.steps_run == 5
