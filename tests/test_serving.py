"""Streaming serving engine: bucket geometry, padding equivalence, and
the no-recompile contract.

The heart of the subsystem is an exactness claim — padding a request
into its shape bucket must not change perm/utility/exposure/compliance
— and a performance claim — a mixed-shape stream compiles nothing after
warmup. Both are asserted here; the recompile assertion goes through
the engine's per-bucket jit cache sizes (1 == exactly the warmed
executable).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.constraints import dcg_discount
from repro.core.predictors import (
    KNNLambdaPredictor,
    LinearLambdaPredictor,
    MeanLambdaPredictor,
)
from repro.core.ranking import rank_given_lambda
from repro.serving import (
    LAM_TAG,
    RankRequest,
    Scenario,
    ServingEngine,
    bucket_for,
    ceil_pow2,
    k_tier,
    make_stream,
)

# ---------------------------------------------------------------------------
# Bucket geometry
# ---------------------------------------------------------------------------


def test_ceil_pow2_boundaries():
    assert ceil_pow2(128, 128) == 128       # exact boundary: no inflation
    assert ceil_pow2(129, 128) == 256       # one past: next power of two
    assert ceil_pow2(1, 128) == 128         # floor applies
    assert ceil_pow2(1024, 128) == 1024


def test_k_tier_and_oversize_fallback():
    assert k_tier(3) == 4
    assert k_tier(4) == 4                   # exact tier boundary
    assert k_tier(5) == 8
    assert k_tier(32) == 32
    assert k_tier(40) == 64                 # oversize: pow2 fallback


def test_bucket_for_clamps_and_validates():
    b = bucket_for(m1=100, m2=100, K=2, tag=LAM_TAG, batch=8)
    assert b.m1 == 128 and b.m2 == 128      # m2 ceiling clamped to m1 ceiling
    with pytest.raises(ValueError):
        bucket_for(m1=50, m2=51, K=2, tag=LAM_TAG, batch=8)
    b2 = bucket_for(m1=500, m2=50, K=5, tag="x", batch=16)
    assert (b2.m1, b2.m2, b2.K, b2.batch) == (512, 64, 8, 16)


# ---------------------------------------------------------------------------
# Padding equivalence: engine result == direct unpadded hot path
# ---------------------------------------------------------------------------


def _direct(req, lam):
    """Unbatched, unpadded reference through the core online path."""
    return rank_given_lambda(
        jnp.asarray(req.u)[None], jnp.asarray(req.a)[None],
        jnp.asarray(req.b)[None], jnp.asarray(lam)[None],
        jnp.asarray(req.gamma), m2=req.m2, eps=1e-4)


def _check_match(result, ref):
    np.testing.assert_array_equal(result.perm, np.asarray(ref.perm[0]))
    np.testing.assert_allclose(result.utility, float(ref.utility[0]),
                               rtol=1e-5)
    np.testing.assert_allclose(result.exposure, np.asarray(ref.exposure[0]),
                               rtol=1e-5, atol=1e-6)
    assert result.compliant == bool(ref.compliant[0])


def test_pad_unpad_roundtrip_matches_unbatched():
    reqs = make_stream(n_requests=24, seed=11)   # all carry lam directly
    eng = ServingEngine(max_batch=8, max_wait_ms=1.0)
    by_rid = {r.rid: r for r in eng.serve_stream(reqs)}
    assert len(by_rid) == len(reqs)
    for req in reqs:
        _check_match(by_rid[req.rid], _direct(req, req.lam))


def test_pad_unpad_roundtrip_predictor_path():
    rng = np.random.default_rng(3)
    d, K = 12, 5
    X_db = rng.normal(size=(100, d)).astype(np.float32)
    lam_db = np.abs(rng.normal(size=(100, K))).astype(np.float32)
    knn = KNNLambdaPredictor.fit(X_db, lam_db, k=5)
    eng = ServingEngine(max_batch=4, max_wait_ms=1.0)
    eng.register_predictor("arch", knn, d_cov=d)
    mix = (Scenario("s", m1=300, m2=30, K=K, tag="arch", d_cov=d),)
    reqs = make_stream(mix, n_requests=12, seed=5)
    by_rid = {r.rid: r for r in eng.serve_stream(reqs)}
    for req in reqs:
        lam = np.asarray(knn.predict(jnp.asarray(req.X)[None]))[0]
        _check_match(by_rid[req.rid], _direct(req, lam))


def test_fused_executor_matches_xla_executor():
    reqs = make_stream(n_requests=8, seed=7)
    res_x = {r.rid: r for r in ServingEngine(
        max_batch=4, max_wait_ms=1.0, executor="xla").serve_stream(reqs)}
    res_f = {r.rid: r for r in ServingEngine(
        max_batch=4, max_wait_ms=1.0, executor="fused").serve_stream(reqs)}
    for rid in res_x:
        # the fused executor's rank+audit kernel mirrors the XLA audit
        # op-for-op, so equality is bitwise, not just allclose
        np.testing.assert_array_equal(res_f[rid].perm, res_x[rid].perm)
        np.testing.assert_array_equal(res_f[rid].exposure,
                                      res_x[rid].exposure)
        assert res_f[rid].utility == res_x[rid].utility
        assert res_f[rid].compliant == res_x[rid].compliant


# ---------------------------------------------------------------------------
# Flush triggers (sync engine: pipeline_depth=0 makes submit/poll return
# the flushed batch inline, so the trigger -> result mapping is exact)
# ---------------------------------------------------------------------------


def _tiny_request(rid, m1=64, m2=8, K=2):
    rng = np.random.default_rng(rid)
    return RankRequest(
        rid=rid, u=rng.uniform(1, 5, m1).astype(np.float32),
        a=(rng.random((K, m1)) < 0.3).astype(np.float32),
        b=np.zeros(K, np.float32), m2=m2,
        lam=np.zeros(K, np.float32),
        gamma=np.asarray(dcg_discount(m2), np.float32))


def test_capacity_flush_fires_on_full_batch():
    eng = ServingEngine(max_batch=4, max_wait_ms=1e9, pipeline_depth=0)
    out = []
    for i in range(4):
        out += eng.submit(_tiny_request(i), now=0.0)
    assert sorted(r.rid for r in out) == [0, 1, 2, 3]
    assert eng.metrics.capacity_flushes == 1


def test_capacity_flush_retires_async_with_pipeline():
    """Same stream through the pipelined engine: the capacity flush
    dispatches without blocking and the batch retires by drain time."""
    eng = ServingEngine(max_batch=4, max_wait_ms=1e9, pipeline_depth=2)
    out = []
    for i in range(4):
        out += eng.submit(_tiny_request(i), now=0.0)
    out += eng.drain()
    assert sorted(r.rid for r in out) == [0, 1, 2, 3]
    assert eng.metrics.capacity_flushes == 1


def test_deadline_flush_fires_on_max_wait():
    eng = ServingEngine(max_batch=4, max_wait_ms=2.0, pipeline_depth=0)
    assert eng.submit(_tiny_request(0), now=0.0) == []
    assert eng.poll(now=0.001) == []            # 1 ms: under deadline
    out = eng.poll(now=0.003)                   # 3 ms: over deadline
    assert [r.rid for r in out] == [0]
    assert eng.metrics.deadline_flushes == 1
    assert out[0].wait_ms > 0


def test_drain_flushes_everything():
    eng = ServingEngine(max_batch=8, max_wait_ms=1e9, pipeline_depth=0)
    for i in range(3):
        eng.submit(_tiny_request(i))
    out = eng.drain()
    assert len(out) == 3 and eng.metrics.drain_flushes == 1


# ---------------------------------------------------------------------------
# The no-recompile contract (acceptance criterion)
# ---------------------------------------------------------------------------


def test_mixed_stream_no_recompiles_after_warmup():
    """>= 256 requests, >= 2 archs, >= 3 (m1, m2) geometries: after
    warmup, zero recompilations — via the engine counter AND the
    per-bucket jit cache sizes."""
    rng = np.random.default_rng(0)
    d = 16
    knn = KNNLambdaPredictor.fit(
        rng.normal(size=(64, d)).astype(np.float32),
        np.abs(rng.normal(size=(64, 5))).astype(np.float32), k=5)
    mean = MeanLambdaPredictor.fit(
        np.zeros((4, d), np.float32),
        np.abs(rng.normal(size=(4, 3))).astype(np.float32))
    eng = ServingEngine(max_batch=16, max_wait_ms=2.0)
    eng.register_predictor("sasrec", knn, d_cov=d)
    eng.register_predictor("deepfm", mean, d_cov=d)
    mix = (
        Scenario("feed", m1=500, m2=50, K=5, weight=3.0,
                 tag="sasrec", d_cov=d),
        Scenario("strip", m1=1000, m2=20, K=3, weight=2.0,
                 tag="deepfm", d_cov=d),
        Scenario("notif", m1=120, m2=8, K=3, weight=1.0),     # raw-lam arch
        Scenario("retrieval", m1=2000, m2=50, K=8, weight=1.0),
    )
    reqs = make_stream(mix, n_requests=256, seed=9)
    assert len({(eng.bucket_of(r).m1, eng.bucket_of(r).m2)
                for r in reqs}) >= 3
    assert len({eng.bucket_of(r).tag for r in reqs}) >= 2

    eng.warmup(reqs)
    compiles_at_warmup = eng.metrics.compiles
    results = []
    for r in reqs:
        results += eng.submit(r)
        results += eng.poll()
    results += eng.drain()

    assert len(results) == 256
    assert eng.metrics.compiles == compiles_at_warmup
    assert eng.metrics.compiles_post_warmup == 0
    assert eng.metrics.oversize_requests == 0
    # jit cache stats: exactly the one warmed executable per bucket
    sizes = eng.jit_cache_sizes()
    assert sizes and all(v == 1 for v in sizes.values()), sizes


def test_oversize_request_is_served_and_counted():
    """A geometry outside the warmed lattice still gets served (new
    bucket compiled on demand) and is visible in the metrics."""
    eng = ServingEngine(max_batch=4, max_wait_ms=1.0)
    small = [_tiny_request(i) for i in range(8)]
    eng.warmup(small)
    for r in small:
        eng.submit(r)
    eng.drain()
    assert eng.metrics.compiles_post_warmup == 0
    big = _tiny_request(99, m1=5000, m2=64, K=40)   # oversize K -> pow2 tier
    eng.submit(big)
    out = eng.drain()
    assert [r.rid for r in out] == [99]
    assert eng.metrics.oversize_requests == 1
    assert eng.metrics.compiles_post_warmup == 1
    _check_match(out[0], _direct(big, big.lam))


class _CountingPredictor:
    """Delegating predictor that counts PYTHON invocations of predict.
    Inside a jit'd bucket executable, predict runs once per TRACE
    (warmup) and never again — a per-batch count increase would mean λ̂
    was being dispatched as a separate device program."""

    def __init__(self, inner):
        self.inner = inner
        self.calls = 0

    def predict(self, X):
        self.calls += 1
        return self.inner.predict(X)


@pytest.mark.parametrize("executor", ["xla", "fused"])
def test_covariate_stream_single_dispatch_per_batch(executor):
    """The single-dispatch contract (acceptance criterion): a
    covariate-carrying stream executes EXACTLY ONE device dispatch per
    flushed micro-batch — λ̂ prediction lives inside the bucket
    executable (kernels.ops.predict_rank_audited), never as a second
    program. The assertions with teeth: the per-bucket jit caches hold
    exactly the one warmed executable (a retracing predict path would
    grow them), and the predictor's Python predict() is never
    re-entered after warmup (an eager or separately-jitted predict
    would re-enter it per flush). The executable-call counter is the
    accounting surface those facts certify."""
    rng = np.random.default_rng(4)
    d, K = 10, 4
    knn = KNNLambdaPredictor.fit(
        rng.normal(size=(96, d)).astype(np.float32),
        np.abs(rng.normal(size=(96, K))).astype(np.float32), k=5)
    counting = _CountingPredictor(MeanLambdaPredictor.fit(
        np.zeros((4, d), np.float32),
        np.abs(rng.normal(size=(4, K))).astype(np.float32)))
    eng = ServingEngine(max_batch=8, max_wait_ms=2.0, executor=executor)
    eng.register_predictor("knn_arch", knn, d_cov=d)
    eng.register_predictor("counted_arch", counting, d_cov=d)
    mix = (
        Scenario("feed", m1=300, m2=20, K=K, weight=2.0,
                 tag="knn_arch", d_cov=d),
        Scenario("strip", m1=600, m2=10, K=K, weight=1.0,
                 tag="counted_arch", d_cov=d),
    )
    reqs = make_stream(mix, n_requests=48, seed=13)
    assert all(r.X is not None for r in reqs)    # covariate-only stream

    eng.warmup(reqs)
    calls_after_warmup = counting.calls
    results = eng.serve_stream(reqs)
    assert len(results) == 48

    m = eng.metrics
    assert m.batches > 0
    assert m.executable_calls == m.batches       # one dispatch per flush
    assert m.summary()["dispatches_per_batch"] == 1.0
    assert m.compiles_post_warmup == 0
    sizes = eng.jit_cache_sizes()
    assert sizes and all(v == 1 for v in sizes.values()), sizes
    # predict() was traced into the executable, not dispatched per batch
    assert counting.calls == calls_after_warmup
    # kernel-launch accounting: every fused-executor batch carries ONE
    # Pallas kernel launch — the KNN buckets included, now that the
    # single-grid predict+rank+audit kernel replaced the two-kernel
    # chain; the xla executor launches none.
    if executor == "fused":
        assert m.kernel_launches == m.batches
        assert m.summary()["kernel_launches_per_batch"] == 1.0
    else:
        assert m.kernel_launches == 0

    # and the answers are the two-stage oracle's, per request
    by_rid = {r.rid: r for r in results}
    for req in reqs:
        pred = knn if req.tag == "knn_arch" else counting.inner
        lam = np.asarray(pred.predict(jnp.asarray(req.X)[None]))[0]
        _check_match(by_rid[req.rid], _direct(req, lam))
    eng.close()


def test_fused_predictor_executor_matches_xla_executor():
    """xla and fused executors agree on a covariate stream — the fused
    path's in-kernel λ̂ prologue (linear/mean) and fused KNN weighting
    produce the same results the two-stage XLA body does."""
    rng = np.random.default_rng(6)
    d, K = 8, 3
    lin = LinearLambdaPredictor.fit(
        jnp.asarray(rng.uniform(0, 1, (64, d)), jnp.float32),
        jnp.asarray(np.abs(rng.normal(size=(64, K))), jnp.float32))
    mix = (Scenario("cov", m1=260, m2=16, K=K, tag="lin", d_cov=d),)
    reqs = make_stream(mix, n_requests=16, seed=3)
    res = {}
    for executor in ("xla", "fused"):
        eng = ServingEngine(max_batch=4, max_wait_ms=1.0, executor=executor)
        eng.register_predictor("lin", lin, d_cov=d)
        res[executor] = {r.rid: r for r in eng.serve_stream(reqs)}
        eng.close()
    for rid in res["xla"]:
        np.testing.assert_array_equal(res["fused"][rid].perm,
                                      res["xla"][rid].perm)
        np.testing.assert_array_equal(res["fused"][rid].exposure,
                                      res["xla"][rid].exposure)
        assert res["fused"][rid].utility == res["xla"][rid].utility
        assert res["fused"][rid].compliant == res["xla"][rid].compliant


def test_predictor_with_too_few_outputs_is_rejected():
    """A predictor cannot price constraints it was not fit for; serving
    them with lam=0 must be an error, not silence."""
    rng = np.random.default_rng(1)
    knn = KNNLambdaPredictor.fit(
        rng.normal(size=(16, 4)).astype(np.float32),
        np.abs(rng.normal(size=(16, 2))).astype(np.float32), k=3)
    eng = ServingEngine(max_batch=4)
    eng.register_predictor("arch", knn, d_cov=4)
    req = _tiny_request(0, K=5)
    req = RankRequest(rid=0, u=req.u, a=req.a, b=req.b, m2=req.m2,
                      X=np.zeros(4, np.float32), tag="arch", gamma=req.gamma)
    with pytest.raises(ValueError, match="shadow prices"):
        eng.submit(req)


def test_metrics_summary_shape():
    eng = ServingEngine(max_batch=8, max_wait_ms=1.0)
    eng.serve_stream(make_stream(n_requests=32, seed=2))
    s = eng.metrics.summary()
    assert s["results"] == 32
    assert 0.0 < s["fill_rate"] <= 1.0
    for q in ("p50", "p95", "p99"):
        assert np.isfinite(s["latency_ms"][q])
    assert 0.0 <= s["compliance"] <= 1.0


# ---------------------------------------------------------------------------
# Paced open-loop load generation (serving.traffic)
# ---------------------------------------------------------------------------


def test_poisson_arrivals_statistics():
    from repro.serving import poisson_arrivals

    arr = poisson_arrivals(4096, qps=100.0, seed=3)
    assert arr.shape == (4096,)
    assert np.all(np.diff(arr) > 0)                 # strictly increasing
    gaps = np.diff(np.concatenate([[0.0], arr]))
    assert abs(gaps.mean() - 0.01) < 0.001          # mean gap ~ 1/qps
    with pytest.raises(ValueError):
        poisson_arrivals(8, qps=0.0)


def test_serve_open_loop_virtual_clock():
    """Open-loop pacing under a deterministic virtual clock: every
    request is submitted at (never before) its scheduled arrival, all
    results come back, and the lag profile is reported."""
    from repro.serving import poisson_arrivals, serve_open_loop

    t = [0.0]

    def clock():
        return t[0]

    def sleep(dt):
        t[0] += dt

    reqs = [_tiny_request(rid) for rid in range(24)]
    arrivals = poisson_arrivals(len(reqs), qps=2000.0, seed=1)
    eng = ServingEngine(max_batch=4, max_wait_ms=0.5, pipeline_depth=0,
                        clock=clock)
    eng.warmup(reqs)
    results, stats = serve_open_loop(eng, reqs, arrivals,
                                     clock=clock, sleep=sleep)
    assert sorted(r.rid for r in results) == list(range(24))
    assert stats["wall_s"] >= float(arrivals[-1])   # pacing was honored
    assert stats["lag_ms"]["max"] >= 0.0
    assert set(stats["lag_ms"]) == {"mean", "p50", "p99", "max", "last"}
    # the virtual clock only advances via sleep(), so submissions can
    # never run ahead of schedule
    assert stats["lag_ms"]["mean"] >= 0.0


class _FakeEngine:
    """Deterministic serve_open_loop stand-in on a virtual clock:
    submit() consumes `cost_s` of clock time (pure engine
    backpressure) and the lag samples fed to observe_submission_lag
    are recorded for inspection."""

    def __init__(self, t, cost_s=0.0):
        self.t = t
        self.cost_s = cost_s
        self.fed = []

    def poll(self):
        return []

    def submit(self, req):
        self.t[0] += self.cost_s
        return [req]

    def drain(self):
        return []

    def observe_submission_lag(self, lag_ms):
        self.fed.append(lag_ms)


def test_open_loop_pacing_overshoot_is_drift_not_queue_lag():
    """Regression (frozen-clock trace): sleep-granularity overshoot
    used to be charged to the engine's submission-lag profile, tripping
    the saturation detector on pacing jitter. Decomposed, it lands
    entirely in drift_ms — queue_lag_ms stays exactly zero, and the
    admission controller is fed those zeros."""
    from repro.serving import serve_open_loop

    t = [0.0]
    overshoot = 1e-3

    def sleep(dt):                              # timer overshoots 1 ms
        t[0] += dt + overshoot

    eng = _FakeEngine(t, cost_s=0.0)            # engine is instantaneous
    n = 16
    arrivals = 0.01 * np.arange(1, n + 1)       # 10 ms gaps >> overshoot
    _, stats = serve_open_loop(eng, list(range(n)), arrivals,
                               clock=lambda: t[0], sleep=sleep)
    assert stats["queue_lag_ms"]["max"] == 0.0  # nothing charged to engine
    assert stats["drift_ms"]["max"] >= overshoot * 1e3
    assert stats["lag_ms"]["max"] == stats["drift_ms"]["max"]
    assert eng.fed == [0.0] * n                 # controller sees no lag


def test_open_loop_backpressure_is_queue_lag_not_drift():
    """The converse trace: a saturated engine (each submit consumes 2x
    the arrival gap) accumulates lateness that is pure queueing — it
    lands entirely in queue_lag_ms, grows over the stream (the
    saturation telltale), and is exactly what feeds the controller."""
    from repro.serving import serve_open_loop

    t = [0.0]

    def sleep(dt):                              # exact virtual timer
        t[0] += dt

    eng = _FakeEngine(t, cost_s=0.02)           # 20 ms service, 10 ms gaps
    n = 16
    arrivals = 0.01 * np.arange(1, n + 1)
    _, stats = serve_open_loop(eng, list(range(n)), arrivals,
                               clock=lambda: t[0], sleep=sleep)
    assert stats["drift_ms"]["max"] == 0.0      # no pacing jitter charged
    assert stats["queue_lag_ms"]["last"] > 0.0
    assert stats["queue_lag_ms"]["last"] == stats["queue_lag_ms"]["max"]
    # lateness at entry grows ~(cost - gap) = 10 ms per request
    np.testing.assert_allclose(eng.fed, 10.0 * np.arange(n), atol=1e-6)
    assert stats["lag_ms"]["last"] == stats["queue_lag_ms"]["last"]


def test_serve_open_loop_length_mismatch_rejected():
    from repro.serving import serve_open_loop

    eng = ServingEngine(max_batch=4, pipeline_depth=0)
    with pytest.raises(ValueError, match="arrival times"):
        serve_open_loop(eng, [_tiny_request(0)], np.asarray([0.0, 1.0]))
    with pytest.raises(ValueError, match="empty request stream"):
        serve_open_loop(eng, [], np.asarray([]))
