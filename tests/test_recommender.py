"""Appendix-B recommender: training recipe, utilities, covariates."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import make_interactions
from repro.models.recommender import PaperRecommender, RecommenderConfig


@pytest.fixture(scope="module")
def trained():
    cfg = RecommenderConfig(n_users=60, n_items=80)
    rec = PaperRecommender(cfg)
    inter = make_interactions(jax.random.key(0), n_users=60, n_items=80,
                              n_obs=8000)
    params = rec.init(jax.random.key(1))
    data = {"uid": inter.uid, "iid": inter.iid, "rating": inter.rating}
    params, losses = rec.train(params, data, key=jax.random.key(2), epochs=5)
    return cfg, rec, params, losses, inter


def test_training_reduces_loss(trained):
    _, _, _, losses, _ = trained
    assert losses[-1] < losses[0]
    assert np.isfinite(losses).all()


def test_predictions_in_rating_range(trained):
    cfg, rec, params, _, _ = trained
    uid = jnp.arange(10)
    iid = jnp.arange(10)
    pred = rec.predict_rating(params, uid, iid)
    assert bool(jnp.all((pred >= 1.0) & (pred <= 5.0)))


def test_utilities_shape_and_range(trained):
    cfg, rec, params, _, _ = trained
    u = rec.utilities(params, jnp.arange(4))
    assert u.shape == (4, cfg.n_items)
    assert bool(jnp.all((u >= 1.0) & (u <= 5.0)))


def test_model_learned_signal(trained):
    """Predicted ratings correlate with ground-truth latent utilities."""
    cfg, rec, params, _, inter = trained
    true = 3.0 + 1.8 * inter.true_user @ inter.true_item.T
    pred = jnp.concatenate([rec.utilities(params, jnp.arange(i, i + 20))
                            for i in (0, 20, 40)])
    corr = np.corrcoef(np.asarray(true).ravel(), np.asarray(pred).ravel())[0, 1]
    assert corr > 0.2, corr


def test_covariates_are_user_embeddings(trained):
    cfg, rec, params, _, _ = trained
    X = rec.user_covariates(params, jnp.arange(5))
    np.testing.assert_allclose(X, params["user_emb"][:5])
