"""End-to-end behaviour of the paper's system (Algorithm 1).

Builds a synthetic MovieLens-style experiment (matched statistics), runs
the full offline stage (batched dual solve -> predictor fit -> eps
tuning) and the online stage for all strategies, and asserts the paper's
QUALITATIVE claims:

  * compliance ordering: none < {mean, knn} <= optimal (Fig. 2);
  * the utility cost of constraints is small (Tables 2-3: utility deltas
    across strategies are marginal);
  * KNN serving is orders faster than per-user optimization (timed on
    CPU; the architectural claim, not a 50 ms wall-clock assertion).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ranking import fit_pipeline, rank_with_strategy
from repro.data.synthetic import build_experiment

STRATEGIES = ("none", "mean", "knn", "optimal")


@pytest.fixture(scope="module")
def experiment():
    exp = build_experiment(
        jax.random.key(11), dataset="movielens", n_users=80, n_items=500,
        m1=200, m2=50, recommender_epochs=2)
    u_tr, X_tr, a_tr = exp.split("train")
    pipe = fit_pipeline(X_tr, u_tr, a_tr, exp.b, exp.gamma, m2=exp.m2,
                        num_iters=400)
    return exp, pipe


@pytest.fixture(scope="module")
def results(experiment):
    exp, pipe = experiment
    u_te, X_te, a_te = exp.split("test")
    out = {}
    for s in STRATEGIES:
        res = rank_with_strategy(pipe, s, X_te, u_te, a_te, exp.b,
                                 dual_iters=400)
        out[s] = {
            "compliance": float(res.compliant.mean()),
            "utility": float(res.utility.mean()),
        }
    return out


def test_compliance_ordering(results):
    c = {s: results[s]["compliance"] for s in STRATEGIES}
    assert c["optimal"] >= 0.9, c
    assert c["knn"] >= c["none"] + 0.3, c
    assert c["mean"] >= c["none"], c
    assert c["optimal"] >= c["knn"] - 0.05, c


def test_utility_cost_of_constraints_is_small(results):
    """Paper: 'the price of imposing diversity constraints is often low'."""
    u_none = results["none"]["utility"]
    for s in ("mean", "knn", "optimal"):
        assert results[s]["utility"] >= 0.90 * u_none, results


def test_rankings_are_valid_permutations(experiment):
    exp, pipe = experiment
    u_te, X_te, a_te = exp.split("test")
    res = rank_with_strategy(pipe, "knn", X_te, u_te, a_te, exp.b)
    perm = np.asarray(res.perm)
    for row in perm:
        assert len(set(row.tolist())) == exp.m2  # no duplicate items


def test_prediction_is_much_faster_than_optimization(experiment):
    """The paper's core speed claim, architecture-level: serving via
    prediction avoids the per-user dual solve entirely."""
    exp, pipe = experiment
    u_te, X_te, a_te = exp.split("test")

    def timed(strategy, n=3):
        rank_with_strategy(pipe, strategy, X_te, u_te, a_te, exp.b,
                           dual_iters=400)  # warm-up/compile
        t0 = time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(
                rank_with_strategy(pipe, strategy, X_te, u_te, a_te, exp.b,
                                   dual_iters=400).perm)
        return (time.perf_counter() - t0) / n

    t_knn = timed("knn")
    t_opt = timed("optimal")
    assert t_knn < t_opt / 3, (t_knn, t_opt)


def test_eps_tuning_selected_from_paper_grid(experiment):
    from repro.core.ranking import EPS_GRID
    _, pipe = experiment
    assert pipe.eps in EPS_GRID


def test_yow_style_mixed_sign_constraints():
    """The YOW table has <= constraints; the sign-flip normalization must
    keep the solver sound."""
    exp = build_experiment(
        jax.random.key(13), dataset="yow", n_users=30, n_items=400,
        m1=150, m2=50, recommender_epochs=1)
    u_tr, X_tr, a_tr = exp.split("train")
    pipe = fit_pipeline(X_tr, u_tr, a_tr, exp.b, exp.gamma, m2=exp.m2,
                        num_iters=400)
    u_te, X_te, a_te = exp.split("test")
    res_opt = rank_with_strategy(pipe, "optimal", X_te, u_te, a_te, exp.b,
                                 dual_iters=400)
    res_none = rank_with_strategy(pipe, "none", X_te, u_te, a_te, exp.b)
    assert float(res_opt.compliant.mean()) >= float(res_none.compliant.mean())
    assert float(res_opt.compliant.mean()) > 0.5
