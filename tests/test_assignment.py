"""Assignment algorithms vs the brute-force oracle + rearrangement props."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.assignment import (
    assignment_value_dense,
    auction,
    brute_force,
    greedy_half_approx,
    perm_to_matrix,
    rank_by_sort,
)

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _rand_S(seed, m1, m2):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(m1, m2)).astype(np.float32)


@given(st.integers(0, 10_000), st.integers(2, 6), st.integers(2, 6))
def test_auction_matches_brute_force(seed, m1, m2):
    if m2 > m1:
        m1, m2 = m2, m1
    S = _rand_S(seed, m1, m2)
    perm_bf = brute_force(S)
    perm_auc = np.asarray(auction(jnp.asarray(S), eps=1e-4))
    v_bf = float(assignment_value_dense(jnp.asarray(S), jnp.asarray(perm_bf)))
    v_auc = float(assignment_value_dense(jnp.asarray(S), jnp.asarray(perm_auc)))
    # auction is eps-optimal
    assert v_auc >= v_bf - 1e-2
    assert len(set(perm_auc.tolist())) == m2  # valid matching


@given(st.integers(0, 10_000), st.integers(2, 7), st.integers(2, 7))
def test_greedy_half_approximation_bound(seed, m1, m2):
    if m2 > m1:
        m1, m2 = m2, m1
    S = np.abs(_rand_S(seed, m1, m2))  # nonneg weights for the 1/2 bound
    perm_g = np.asarray(greedy_half_approx(jnp.asarray(S)))
    perm_bf = brute_force(S)
    v_g = float(assignment_value_dense(jnp.asarray(S), jnp.asarray(perm_g)))
    v_bf = float(assignment_value_dense(jnp.asarray(S), jnp.asarray(perm_bf)))
    assert v_g >= 0.5 * v_bf - 1e-5
    assert len(set(perm_g.tolist())) == m2


@given(st.integers(0, 10_000), st.integers(2, 8), st.integers(1, 8))
def test_rank_by_sort_optimal_for_fixed_discounting(seed, m1, m2):
    """Rearrangement inequality: sorting s equals the brute-force optimum
    of S = s gamma^T (paper Sec. 3.2.1)."""
    if m2 > m1:
        m2 = m1
    rng = np.random.default_rng(seed)
    s = rng.normal(size=(m1,)).astype(np.float32)
    gamma = np.sort(rng.uniform(0.05, 1.0, size=(m2,)))[::-1].copy()
    S = np.outer(s, gamma)
    perm_sort = np.asarray(rank_by_sort(jnp.asarray(s), m2))
    perm_bf = brute_force(S)
    v_sort = float(assignment_value_dense(jnp.asarray(S), jnp.asarray(perm_sort)))
    v_bf = float(assignment_value_dense(jnp.asarray(S), jnp.asarray(perm_bf)))
    assert v_sort >= v_bf - 1e-5


def test_perm_to_matrix_roundtrip():
    perm = jnp.asarray([3, 0, 2])
    P = perm_to_matrix(perm, 5)
    assert P.shape == (5, 3)
    np.testing.assert_allclose(np.asarray(P).sum(axis=0), 1.0)
    S = jnp.arange(15.0).reshape(5, 3)
    assert float(jnp.sum(S * P)) == float(assignment_value_dense(S, perm))


def test_unbalanced_sort_takes_top_m2():
    s = jnp.asarray([0.1, 5.0, -1.0, 3.0])
    perm = rank_by_sort(s, 2)
    np.testing.assert_array_equal(np.asarray(perm), [1, 3])
