# NOTE: no XLA_FLAGS here on purpose — smoke tests and benchmarks must see
# the single real CPU device. Only launch/dryrun.py forces 512 host devices.
import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)
