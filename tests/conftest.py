# NOTE: no XLA_FLAGS here on purpose — smoke tests and benchmarks must see
# the single real CPU device. Only launch/dryrun.py forces 512 host devices.
import jax
import pytest

jax.config.update("jax_enable_x64", False)


class FrozenClock:
    """Deterministic engine clock for timing-sensitive tests: returns
    `t`, advancing only by `tick` per call (0 = truly frozen) or by
    explicit `advance`. Injected as ServingEngine(clock=...) it makes
    deadline hits, admission EWMA seeding, and flush triggers
    reproducible on any CI box — a frozen clock never fires deadline
    flushes, so batch composition is a pure function of the stream."""

    def __init__(self, t0: float = 0.0, tick: float = 0.0):
        self.t = float(t0)
        self.tick = float(tick)

    def __call__(self) -> float:
        t = self.t
        self.t += self.tick
        return t

    def advance(self, dt: float) -> None:
        self.t += float(dt)

    def sleep(self, dt: float) -> None:
        self.advance(dt)


@pytest.fixture
def frozen_clock():
    return FrozenClock()


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)
