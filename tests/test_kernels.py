"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret=True."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.key(42)


@pytest.mark.parametrize("B,N,D,k", [
    (8, 512, 128, 10),
    (3, 300, 64, 5),       # off-tile shapes exercise padding
    (16, 1024, 256, 16),
    (1, 512, 32, 1),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_knn_topk_matches_oracle(B, N, D, k, dtype):
    kq, kd = jax.random.split(jax.random.fold_in(KEY, B * N + D))
    xq = jax.random.normal(kq, (B, D), dtype)
    xdb = jax.random.normal(kd, (N, D), dtype)
    d2k, idxk = ops.knn_topk(xq, xdb, k=k, interpret=True)
    d2r, idxr = ref.knn_topk_ref(xq, xdb, k)
    np.testing.assert_allclose(d2k, d2r, rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-4)
    if dtype == jnp.float32:
        np.testing.assert_array_equal(np.asarray(idxk), np.asarray(idxr))


@pytest.mark.parametrize("n,m1,K,m2", [
    (8, 512, 5, 10),
    (4, 1000, 8, 50),      # the paper's 1000-item scenario
    (8, 2048, 3, 128),     # MAX_KERNEL_M2 boundary
    (2, 600, 1, 1),
])
def test_fused_rank_matches_oracle(n, m1, K, m2):
    ks = jax.random.split(jax.random.fold_in(KEY, n * m1 + K), 3)
    u = jax.random.normal(ks[0], (n, m1))
    a = jax.random.normal(ks[1], (n, K, m1))
    lam = jnp.abs(jax.random.normal(ks[2], (n, K)))
    vk, ik = ops.fused_rank(u, a, lam, m2=m2, interpret=True)
    vr, ir = ref.fused_rank_ref(u, a, lam, m2)
    np.testing.assert_allclose(vk, vr, rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(ik), np.asarray(ir))


def test_fused_rank_xla_fallback_large_m2():
    ks = jax.random.split(KEY, 3)
    u = jax.random.normal(ks[0], (4, 512))
    a = jax.random.normal(ks[1], (4, 2, 512))
    lam = jnp.abs(jax.random.normal(ks[2], (4, 2)))
    vk, ik = ops.fused_rank(u, a, lam, m2=256)     # > MAX_KERNEL_M2 -> XLA
    vr, ir = ref.fused_rank_ref(u, a, lam, 256)
    np.testing.assert_allclose(vk, vr, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("V,D,nb,bag", [
    (100, 32, 8, 4),
    (50, 16, 5, 10),       # off-tile bag count
    (200, 128, 16, 1),
])
@pytest.mark.parametrize("weighted", [False, True])
def test_embedding_bag_matches_oracle(V, D, nb, bag, weighted):
    ks = jax.random.split(jax.random.fold_in(KEY, V + D), 3)
    table = jax.random.normal(ks[0], (V, D))
    idx = jax.random.randint(ks[1], (nb, bag), -2, V)   # includes padding ids
    w = jax.random.normal(ks[2], (nb, bag)) if weighted else None
    got = ops.embedding_bag(table, idx, w, interpret=True)
    want = ref.embedding_bag_ref(table, idx, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_knn_predict_kernel_matches_reference_predictor():
    from repro.core.predictors import knn_predict
    ks = jax.random.split(KEY, 3)
    X_db = jax.random.normal(ks[0], (256, 16))
    lam_db = jnp.abs(jax.random.normal(ks[1], (256, 4)))
    X = jax.random.normal(ks[2], (8, 16))
    got = ops.knn_predict_kernel(X_db, lam_db, X, k=10, interpret=True)
    want = knn_predict(X_db, lam_db, X, k=10)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_embedding_bag_model_twin():
    """models.recsys.embedding_bag (take+segment_sum) == kernel == oracle."""
    from repro.models.recsys import embedding_bag as model_bag
    ks = jax.random.split(KEY, 3)
    table = jax.random.normal(ks[0], (64, 8))
    idx = jax.random.randint(ks[1], (8, 6), -1, 64)
    a = model_bag(table, idx)
    b = ref.embedding_bag_ref(table, idx)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
