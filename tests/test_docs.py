"""Documentation health: relative links resolve, anchors exist, and the
docs/api.md code snippets actually run against the current tree.

This is what the CI docs job executes; it doubles as a local check
(`pytest tests/test_docs.py`). Snippet execution is doctest-style: all
```python blocks in docs/api.md run in order in one shared namespace,
so later snippets can build on earlier ones exactly as a reader would.
"""

import re
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
DOC_FILES = sorted(
    [REPO / "README.md", REPO / "EXPERIMENTS.md", REPO / "ROADMAP.md"]
    + list((REPO / "docs").glob("*.md")))

LINK_RE = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")
CODE_FENCE_RE = re.compile(r"^```", re.M)


def _strip_code_blocks(text: str) -> str:
    """Drop fenced code blocks so example links aren't link-checked."""
    out, keep = [], True
    for line in text.splitlines():
        if line.startswith("```"):
            keep = not keep
            continue
        if keep:
            out.append(line)
    return "\n".join(out)


def _heading_anchors(text: str) -> set:
    """GitHub-style anchors for every markdown heading: lowercase,
    drop everything but word chars / spaces / hyphens, then map each
    space to a hyphen (runs of spaces produce runs of hyphens, exactly
    like GitHub's slugger)."""
    anchors = set()
    for line in _strip_code_blocks(text).splitlines():
        m = re.match(r"#+\s+(.*)", line)
        if not m:
            continue
        slug = m.group(1).strip().lower()
        slug = re.sub(r"[^\w\s-]", "", slug, flags=re.UNICODE)
        anchors.add(slug.replace(" ", "-"))
    return anchors


def _links_of(path: Path):
    return LINK_RE.findall(_strip_code_blocks(path.read_text()))


@pytest.mark.parametrize("doc", DOC_FILES, ids=lambda p: p.name)
def test_relative_links_resolve(doc):
    """Every relative link in README/EXPERIMENTS/ROADMAP/docs/ points
    at a file that exists; fragment links point at a real heading."""
    broken = []
    for link in _links_of(doc):
        if link.startswith(("http://", "https://", "mailto:")):
            continue
        target, _, fragment = link.partition("#")
        target_path = (doc.parent / target).resolve() if target else doc
        if not target_path.exists():
            broken.append(f"{link} -> missing file {target_path}")
            continue
        if fragment and target_path.suffix == ".md":
            anchors = _heading_anchors(target_path.read_text())
            if fragment not in anchors:
                broken.append(f"{link} -> missing anchor #{fragment} "
                              f"(have: {sorted(anchors)})")
    assert not broken, f"{doc.name}: broken links:\n" + "\n".join(broken)


def test_readme_links_docs_tree():
    """README must link every page of the docs/ tree."""
    readme = (REPO / "README.md").read_text()
    for page in ("architecture", "serving", "benchmarks", "api"):
        assert f"docs/{page}.md" in readme, f"README missing docs/{page}.md"


def test_experiments_pipeline_section_cross_linked():
    """EXPERIMENTS §Pipeline and docs/benchmarks.md reference each
    other (satellite: every EXPERIMENTS section is reachable from the
    benchmarks doc)."""
    experiments = (REPO / "EXPERIMENTS.md").read_text()
    benchdoc = (REPO / "docs" / "benchmarks.md").read_text()
    assert "Pipeline" in experiments
    assert "EXPERIMENTS.md#" in benchdoc


def _python_snippets(path: Path):
    blocks, in_block, buf = [], False, []
    for line in path.read_text().splitlines():
        if line.strip().startswith("```python"):
            in_block, buf = True, []
        elif line.strip() == "```" and in_block:
            in_block = False
            blocks.append("\n".join(buf))
        elif in_block:
            buf.append(line)
    return blocks


def test_api_doc_snippets_run():
    """Execute every ```python block in docs/api.md, in order, in one
    namespace — the documented API must actually work as written."""
    blocks = _python_snippets(REPO / "docs" / "api.md")
    assert len(blocks) >= 8, "docs/api.md lost its runnable snippets?"
    ns = {}
    for i, block in enumerate(blocks):
        try:
            exec(compile(block, f"docs/api.md#block{i}", "exec"), ns)
        except Exception as e:
            pytest.fail(f"docs/api.md snippet #{i} failed: {e!r}\n"
                        f"---\n{block}\n---")
