"""The trip-count-aware HLO cost walker (launch/hlo_cost.py)."""

import pytest

from repro.launch.hlo_cost import (
    _buffer_bytes,
    _trip_count,
    hlo_cost,
    parse_module,
)

TOY = """\
HloModule jit_f

%body (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %p = (s32[], f32[8,128]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,128]{1,0} get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  %w = f32[128,128]{1,0} constant({...})
  %ag = f32[8,256]{1,0} all-gather(%x), channel_id=1, replica_groups=[2,4]<=[8], dimensions={1}
  %y = f32[8,128]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[8,128]{1,0}) tuple(%i2, %y)
}

%cond (p2: (s32[], f32[8,128])) -> pred[] {
  %p2 = (s32[], f32[8,128]{1,0}) parameter(0)
  %i3 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i3, %n), direction=LT
}

ENTRY %main (a: f32[8,128]) -> f32[8,128] {
  %a = f32[8,128]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,128]{1,0}) tuple(%zero, %a)
  %w2 = (s32[], f32[8,128]{1,0}) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8,128]{1,0} get-tuple-element(%w2), index=1
}
"""


def test_buffer_bytes():
    assert _buffer_bytes("f32[8,128]{1,0}") == 8 * 128 * 4
    assert _buffer_bytes("bf16[4,4]") == 32
    assert _buffer_bytes("(f32[2], s32[3])") == 8 + 12
    assert _buffer_bytes("pred[]") == 1


def test_parse_module_structure():
    comps, entry = parse_module(TOY)
    assert set(comps) == {"body", "cond", "main"}
    assert entry == "main"
    ops = {o.op for o in comps["body"].ops}
    assert {"dot", "all-gather", "add"} <= ops


def test_trip_count_from_condition():
    comps, _ = parse_module(TOY)
    assert _trip_count(comps["cond"]) == 7


def test_cost_multiplies_loops():
    r = hlo_cost(TOY)
    # dot flops per iter: 2 * (8*128) * 128 ; x7 iterations
    assert r["flops"] == 7 * 2 * 8 * 128 * 128
    # all-gather result bytes per iter x7
    assert r["collectives"]["all-gather"] == 7 * 8 * 256 * 4
    assert r["collectives"]["total"] == r["collectives"]["all-gather"]
    assert r["bytes"] > 0


def test_dus_and_gather_counted_at_touched_size():
    hlo = """\
HloModule m

ENTRY %main (t: f32[1000,64], i: s32[5,1], u: f32[1,64]) -> f32[5,64] {
  %t = f32[1000,64]{1,0} parameter(0)
  %i = s32[5,1]{1,0} parameter(1)
  %u = f32[1,64]{1,0} parameter(2)
  %z = s32[] constant(0)
  %dus = f32[1000,64]{1,0} dynamic-update-slice(%t, %u, %z, %z)
  ROOT %g = f32[5,64]{1,0} gather(%dus, %i), offset_dims={1}, collapsed_slice_dims={0}, start_index_map={0}, index_vector_dim=1, slice_sizes={1,64}
}
"""
    r = hlo_cost(hlo)
    # DUS: 2 * update bytes; gather: 2 * result + indices — NOT the table
    expected = 2 * 64 * 4 + (2 * 5 * 64 * 4 + 5 * 4)
    assert r["bytes"] == expected
