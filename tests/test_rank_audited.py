"""Fused rank+audit kernel vs the rank_given_lambda oracle: BITWISE
parity (perm, utility, exposure, compliant) across a shape sweep,
bucket-padded serving batches (trailing-zero gamma rows, phantom rows),
the m2 = MAX_KERNEL_M2 edge, and the XLA fallback — plus the payload
topk_merge primitive and the tune_eps tie-break regression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ranking import EPS_GRID, rank_given_lambda, tune_eps
from repro.kernels import ops
from repro.kernels.common import NEG_INF, topk_merge
from repro.kernels.fused_rank import MAX_KERNEL_M2

KEY = jax.random.key(7)

FIELDS = ("perm", "utility", "exposure", "compliant")


def _problem(n, m1, K, m2, salt=0):
    ks = jax.random.split(jax.random.fold_in(KEY, n * m1 + K + salt), 5)
    u = jax.random.uniform(ks[0], (n, m1), minval=1.0, maxval=5.0)
    a = (jax.random.uniform(ks[1], (n, K, m1)) < 0.15).astype(jnp.float32)
    lam = jnp.abs(jax.random.normal(ks[2], (n, K)))
    b = jnp.abs(jax.random.normal(ks[3], (n, K)))
    gamma = jnp.abs(jax.random.normal(ks[4], (n, m2)))
    return u, a, b, lam, gamma


def _assert_bitwise(got, want):
    for field in FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(got, field)), np.asarray(getattr(want, field)),
            err_msg=f"rank+audit parity broke on {field}")


@pytest.mark.parametrize("n,m1,K,m2", [
    (8, 512, 5, 10),
    (4, 1000, 8, 50),              # the paper's 1000-item scenario
    (8, 2048, 3, MAX_KERNEL_M2),   # m2 edge: the largest kernel path
    (2, 600, 1, 1),
    (3, 700, 2, 8),                # off-tile n and m1 exercise padding
])
def test_rank_audited_matches_oracle_bitwise(n, m1, K, m2):
    u, a, b, lam, gamma = _problem(n, m1, K, m2)
    got = ops.rank_audited(u, a, b, lam, gamma, m2=m2, interpret=True)
    want = rank_given_lambda(u, a, b, lam, gamma, m2=m2)
    _assert_bitwise(got, want)
    # sanity: the audit actually discriminates on these problems
    assert np.asarray(want.compliant).ndim == 1


def test_rank_audited_shared_broadcast_forms():
    """(K, m1) a, (K,) b, (m2,) gamma broadcast exactly like the oracle."""
    u, a, b, lam, gamma = _problem(6, 512, 4, 16)
    got = ops.rank_audited(u, a[0], b[0], lam, gamma[0], m2=16,
                           interpret=True)
    want = rank_given_lambda(u, a[0], b[0], lam, gamma[0], m2=16)
    _assert_bitwise(got, want)


def test_rank_audited_bucket_padded_batch():
    """An engine-style padded micro-batch: phantom rows, NEG_FILL
    candidate padding, zero constraint rows, trailing-zero gamma —
    kernel and oracle agree bitwise on the whole padded problem."""
    from repro.serving import assemble_batch, bucket_for, make_request
    from repro.serving.traffic import DEFAULT_MIX

    rng = np.random.default_rng(0)
    reqs = [make_request(rng, DEFAULT_MIX[0], rid) for rid in range(5)]
    bucket = bucket_for(m1=max(r.u.shape[0] for r in reqs),
                        m2=reqs[0].m2, K=reqs[0].a.shape[0],
                        tag="_lam", batch=8)        # 3 phantom rows
    staged = assemble_batch(reqs, bucket)
    u = jnp.asarray(staged["u"])
    a = jnp.asarray(staged["a"])
    b = jnp.asarray(staged["b"])
    lam = jnp.asarray(staged["lam"])
    gamma = jnp.asarray(staged["gamma"])
    assert float(gamma[0, -1]) == 0.0 or bucket.m2 == reqs[0].m2

    got = ops.rank_audited(u, a, b, lam, gamma, m2=bucket.m2, interpret=True)
    want = rank_given_lambda(u, a, b, lam, gamma, m2=bucket.m2)
    # real rows: bitwise on every field
    n_real = len(reqs)
    for field in FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(got, field))[:n_real],
            np.asarray(getattr(want, field))[:n_real],
            err_msg=f"padded-batch parity broke on {field}")
    # phantom rows (u uniformly NEG_FILL == the merge's init sentinel):
    # their perm is unspecified — every candidate ties with the empty
    # running buffer — and the engine unpads them away before results
    # leave. The AUDIT outputs still agree bitwise: zero gamma makes
    # utility/exposure exactly 0.0 and compliance trivially true.
    for field in ("utility", "exposure", "compliant"):
        np.testing.assert_array_equal(
            np.asarray(getattr(got, field))[n_real:],
            np.asarray(getattr(want, field))[n_real:],
            err_msg=f"phantom-row audit parity broke on {field}")
    np.testing.assert_array_equal(np.asarray(got.utility[n_real:]), 0.0)


def test_rank_audited_trailing_zero_gamma_rows():
    """Per-request gamma rows with zeroed trailing slots (bucket-padded
    m2) leave utility/exposure identical to the unpadded problem."""
    n, m1, K, m2_real, m2_pad = 4, 512, 3, 10, 16
    u, a, b, lam, gamma = _problem(n, m1, K, m2_real)
    gamma_pad = jnp.pad(gamma, ((0, 0), (0, m2_pad - m2_real)))
    got = ops.rank_audited(u, a, b, lam, gamma_pad, m2=m2_pad,
                           interpret=True)
    want = rank_given_lambda(u, a, b, lam, gamma, m2=m2_real)
    np.testing.assert_array_equal(
        np.asarray(got.perm[:, :m2_real]), np.asarray(want.perm))
    np.testing.assert_array_equal(
        np.asarray(got.utility), np.asarray(want.utility))
    np.testing.assert_array_equal(
        np.asarray(got.exposure), np.asarray(want.exposure))
    np.testing.assert_array_equal(
        np.asarray(got.compliant), np.asarray(want.compliant))


def test_rank_audited_xla_fallback_large_m2():
    n, m1, K, m2 = 4, 700, 3, MAX_KERNEL_M2 + 72
    u, a, b, lam, gamma = _problem(n, m1, K, m2)
    got = ops.rank_audited(u, a, b, lam, gamma, m2=m2)   # > MAX -> XLA
    want = rank_given_lambda(u, a, b, lam, gamma, m2=m2)
    _assert_bitwise(got, want)


def test_rank_given_lambda_kernel_backend_route():
    """backend='kernel' emits the same RankingOutput as the jnp path."""
    u, a, b, lam, gamma = _problem(8, 512, 4, 12, salt=3)
    want = rank_given_lambda(u, a, b, lam, gamma, m2=12)
    got = rank_given_lambda(u, a, b, lam, gamma, m2=12, backend="kernel")
    _assert_bitwise(got, want)
    with pytest.raises(ValueError):
        rank_given_lambda(u, a, b, lam, gamma, m2=12, backend="nope")


def test_topk_merge_payload_carry():
    """Payload columns follow their winners through the streaming merge
    exactly, including across the running-buffer boundary."""
    k, B, T = 4, 3, 16
    ks = jax.random.split(KEY, 4)
    run_v = jnp.sort(jax.random.normal(ks[0], (B, k)), axis=-1)[:, ::-1]
    run_i = jnp.arange(k)[None, :].repeat(B, 0)
    tile_v = jax.random.normal(ks[1], (B, T))
    tile_i = 100 + jnp.arange(T)[None, :].repeat(B, 0)
    run_p = {"u": run_v * 2.0, "a": jnp.stack([run_v, -run_v], axis=1)}
    tile_p = {"u": tile_v * 2.0, "a": jnp.stack([tile_v, -tile_v], axis=1)}
    out_v, out_i, out_p = topk_merge(run_v, run_i, tile_v, tile_i, k,
                                     run_payload=run_p, tile_payload=tile_p)
    # oracle: top-k of the union, payload = f(value) must track winners
    cand_v = np.concatenate([run_v, tile_v], axis=-1)
    order = np.argsort(-cand_v, axis=-1, kind="stable")[:, :k]
    want_v = np.take_along_axis(cand_v, order, axis=-1)
    np.testing.assert_array_equal(np.asarray(out_v), want_v)
    np.testing.assert_array_equal(np.asarray(out_p["u"]), want_v * 2.0)
    np.testing.assert_array_equal(np.asarray(out_p["a"][:, 0]), want_v)
    np.testing.assert_array_equal(np.asarray(out_p["a"][:, 1]), -want_v)


def test_topk_merge_no_payload_unchanged():
    """The payload-free signature still returns the 2-tuple contract."""
    run_v = jnp.full((2, 3), NEG_INF)
    run_i = jnp.zeros((2, 3), jnp.int32)
    tile_v = jnp.asarray([[1.0, 3.0, 2.0, 0.0]] * 2)
    tile_i = jnp.arange(4)[None, :].repeat(2, 0)
    out = topk_merge(run_v, run_i, tile_v, tile_i, 3)
    assert len(out) == 2
    np.testing.assert_array_equal(np.asarray(out[1]),
                                  [[1, 2, 0]] * 2)


# ---------------------------------------------------------------------------
# tune_eps tie-breaking (ascending grid regression)
# ---------------------------------------------------------------------------

def test_eps_grid_is_ascending():
    assert list(EPS_GRID) == sorted(EPS_GRID)
    assert EPS_GRID[0] == 0.0 and EPS_GRID[1] == pytest.approx(1e-4)


def test_tune_eps_flat_landscape_keeps_smallest_eps():
    """eps = 0 ties the two candidates (violation); every eps > 0 breaks
    the tie toward the constrained item (zero violation, FLAT in eps).
    The documented rule — ties -> smaller eps — demands the smallest
    positive grid point, 1e-4; a descending or i*10^-j-ordered sweep
    would return 0.1."""
    u = jnp.asarray([[1.5, 1.0]])
    a = jnp.asarray([[[0.0, 1.0]]])
    b = jnp.asarray([[0.5]])
    lam = jnp.asarray([[0.5]])      # eps=0: s = [1.5, 1.5] -> exact tie
    gamma = jnp.asarray([1.0])
    # sanity: eps=0 -> tie -> item 0 -> violated; eps>0 -> item 1 -> ok
    out0 = rank_given_lambda(u, a, b, lam, gamma, m2=1, eps=0.0)
    assert not bool(out0.compliant[0])
    out1 = rank_given_lambda(u, a, b, lam, gamma, m2=1, eps=0.1)
    assert bool(out1.compliant[0])
    assert tune_eps(u, a, b, lam, gamma, m2=1) == pytest.approx(1e-4)


def test_tune_eps_all_flat_returns_zero():
    """Fully flat landscape (b = 0: always compliant) -> eps stays 0.0."""
    u, a, b, lam, gamma = _problem(2, 128, 2, 4)
    b0 = jnp.zeros_like(b)
    assert tune_eps(u, a, b0, lam, gamma[0], m2=4) == 0.0
