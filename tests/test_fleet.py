"""Fault-tolerant replica fleet: health state machine, deterministic
fault plans, consistent-hash routing, hedged retries with rid dedup,
crash failover, supervised restart from epoch checkpoints, and the
full seeded chaos acceptance run.

Everything timing-sensitive runs on FrozenClock (router and engines
both), so health transitions, backoff schedules, and batch composition
are pure functions of the stream + the fault plan — the chaos scenario
replays identically on any box.
"""

import threading

import numpy as np
import pytest

from conftest import FrozenClock

from repro.checkpoint import CheckpointStore
from repro.core.predictors import MeanLambdaPredictor
from repro.data.synthetic import DriftSpec
from repro.serving import (
    DEAD,
    HEALTHY,
    RECOVERING,
    SUSPECT,
    FaultInjector,
    FaultPlan,
    FleetRouter,
    HealthConfig,
    RankRequest,
    RefreshLane,
    ReplicaCrash,
    ReplicaFaults,
    ReplicaHealth,
    Scenario,
    ServingEngine,
    Shed,
    backoff_s,
    make_drift_stream,
    make_stream,
)

TAG = "arch"
D_COV, K = 10, 4


# ---------------------------------------------------------------------------
# Health state machine (pure, clock-injected)
# ---------------------------------------------------------------------------


def _health(**kw):
    return ReplicaHealth("r", HealthConfig(**kw))


def test_health_config_validation():
    with pytest.raises(ValueError, match="dead_after_s"):
        HealthConfig(suspect_after_s=1.0, dead_after_s=0.5)
    with pytest.raises(ValueError, match="lag_hysteresis"):
        HealthConfig(lag_hysteresis=0.0)


def test_heartbeat_staleness_walks_suspect_then_dead():
    h = _health(suspect_after_s=1.0, dead_after_s=3.0)
    h.heartbeat(0.0)
    assert h.evaluate(0.5) == HEALTHY
    assert h.evaluate(1.5) == SUSPECT
    assert h.evaluate(2.9) == SUSPECT
    assert h.evaluate(3.0) == DEAD
    # DEAD is absorbing: a straggler heartbeat does not resurrect
    h.heartbeat(3.1)
    assert h.evaluate(3.2) == DEAD
    assert [t[1:3] for t in h.transitions] == [
        (HEALTHY, SUSPECT), (SUSPECT, DEAD)]


def test_lag_ewma_suspects_and_recovers_with_hysteresis():
    h = _health(lag_suspect_ms=100.0, lag_hysteresis=0.5, lag_alpha=1.0)
    h.heartbeat(0.0)
    h.observe_lag(150.0)
    assert h.evaluate(0.01) == SUSPECT
    # under the ENTRY threshold but inside the hysteresis band: stays
    h.observe_lag(80.0)
    h.heartbeat(0.02)
    assert h.evaluate(0.02) == SUSPECT
    # below hysteresis * threshold: recovers
    h.observe_lag(10.0)
    h.heartbeat(0.03)
    assert h.evaluate(0.03) == HEALTHY


def test_failures_escalate_and_fatal_goes_straight_to_dead():
    h = _health(fail_threshold=3)
    h.heartbeat(0.0)
    h.on_failure(0.01)
    assert h.state == SUSPECT
    h.on_success(0.02)                          # resets the counter
    assert h.consecutive_failures == 0
    for i in range(3):
        h.on_failure(0.03 + i * 0.01)
    assert h.state == DEAD
    h2 = _health()
    h2.heartbeat(0.0)
    h2.on_failure(0.01, fatal=True)
    assert h2.state == DEAD


def test_recovery_protocol_and_failed_restart():
    h = _health()
    with pytest.raises(RuntimeError, match="only DEAD"):
        h.begin_recovery(0.0)
    h.on_failure(0.0, fatal=True)
    h.begin_recovery(1.0)
    assert h.state == RECOVERING and not h.routable
    assert h.evaluate(100.0) == RECOVERING      # deadline rules don't touch it
    h.fail_recovery(2.0)
    assert h.state == DEAD
    h.begin_recovery(3.0)
    h.mark_recovered(4.0)
    assert h.state == HEALTHY and h.consecutive_failures == 0
    assert h.last_heartbeat == 4.0


def test_backoff_is_deterministic_capped_and_jittered():
    xs = [backoff_s(a, base_s=0.1, cap_s=1.0, seed=3) for a in range(8)]
    assert xs == [backoff_s(a, base_s=0.1, cap_s=1.0, seed=3)
                  for a in range(8)]            # replayable
    for a, x in enumerate(xs):
        raw = min(1.0, 0.1 * 2 ** a)
        assert 0.5 * raw <= x <= raw            # jitter in [0.5, 1.0]
    assert backoff_s(50, base_s=0.1, cap_s=1.0, seed=3) <= 1.0
    assert backoff_s(0, seed=1) != backoff_s(0, seed=2)
    with pytest.raises(ValueError):
        backoff_s(-1)


# ---------------------------------------------------------------------------
# Fault plans + injector
# ---------------------------------------------------------------------------


def test_chaos_plan_is_seed_deterministic():
    names = ["a", "b", "c"]
    p1, p2 = (FaultPlan.chaos(names, seed=5) for _ in range(2))
    assert p1 == p2
    assert p1 != FaultPlan.chaos(names, seed=6)
    assert p1.faults_for("a").crash_at_batch is not None
    assert p1.faults_for("a").kill_during_drain
    assert p1.faults_for("b").blackhole_after is not None
    assert p1.faults_for("c").slow_ms > 0
    assert not FaultPlan.none(names).faults_for("a").any()
    with pytest.raises(ValueError, match=">= 3"):
        FaultPlan.chaos(["a", "b"])


def test_injector_crash_at_batch_and_blackhole_window():
    inj = FaultInjector(ReplicaFaults(crash_at_batch=2, blackhole_after=1,
                                      blackhole_until=3), "r")
    inj._before_flush()
    inj._before_flush()
    with pytest.raises(ReplicaCrash):
        inj._before_flush()                     # batch index 2
    with pytest.raises(ReplicaCrash):
        inj._before_flush()                     # crashed: stays down
    assert [inj.heartbeat_delivered() for _ in range(5)] == [False] * 5
    inj2 = FaultInjector(ReplicaFaults(blackhole_after=1, blackhole_until=3),
                         "r2")
    assert [inj2.heartbeat_delivered() for _ in range(5)] == [
        True, False, False, True, True]


def test_injector_restore_clears_one_shot_faults_but_keeps_drain_kill():
    inj = FaultInjector(ReplicaFaults(crash_at_batch=0,
                                      kill_during_drain=True), "r")
    with pytest.raises(ReplicaCrash):
        inj._before_flush()
    inj.restore()
    inj._before_flush()                         # crash cleared
    inj.draining = True
    with pytest.raises(ReplicaCrash):
        inj._before_flush()                     # drain kill still armed
    inj.restore()
    inj.draining = True
    inj._before_flush()                         # but fires only once


# ---------------------------------------------------------------------------
# Router: ring, clean serving, hedging, failover, restart
# ---------------------------------------------------------------------------


def _lam_factory(name):
    return ServingEngine(max_batch=4, max_wait_ms=1e9, pipeline_depth=1,
                         clock=FrozenClock())


def _router(factory=_lam_factory, n=3, **kw):
    kw.setdefault("clock", FrozenClock(tick=1e-4))
    kw.setdefault("heartbeat_interval_s", float("inf"))
    kw.setdefault("backoff_base_s", 1e-4)
    kw.setdefault("backoff_cap_s", 1e-3)
    return FleetRouter(factory, n, **kw)


def _one_bucket_stream(n, seed=0):
    """All requests land in ONE bucket (fixed geometry, raw lam)."""
    mix = (Scenario("s", m1=64, m2=8, K=4, m1_jitter=0.0),)
    return make_stream(mix, n_requests=n, seed=seed)


def test_ring_owners_are_deterministic_and_cover_all_replicas():
    r1, r2 = _router(), _router()
    for name in ("lam/64/8/4/b4", "lam/128/16/4/b4", "arch/256/8/8/b4"):
        o1, o2 = r1._owners(name), r2._owners(name)
        assert o1 == o2                         # replayable (blake2b, not
        assert sorted(o1) == [0, 1, 2]          # process-salted hash())
    # vnodes spread primaries: over many keys no replica owns everything
    primaries = {r1._owners(f"bucket/{i}")[0] for i in range(64)}
    assert primaries == {0, 1, 2}
    r1.close(), r2.close()


def test_clean_fleet_serves_every_request_exactly_once():
    reqs = _one_bucket_stream(32)
    router = _router()
    res = router.serve_stream(reqs)
    assert sorted(r.rid for r in res) == list(range(32))
    s = router.fleet_summary()
    assert s["submitted"] == 32 and s["served"] == 32
    assert s["lost"] == 0 and s["orphaned_futures"] == 0
    assert s["crashes"] == 0 and s["restarts"] == 0
    # only primary + backup warmed the bucket group (replication=1)
    warmed = [rep for rep in router.replicas if rep.warm_buckets]
    assert len(warmed) == 2
    assert warmed[0].warm_buckets == warmed[1].warm_buckets
    router.close()


def test_fleet_results_match_single_engine_bitwise():
    """Routing is transparent: a 3-replica fleet serves bitwise what a
    single engine serves (same predictor state, same bucket geometry —
    rows are independent, so batch composition can't matter)."""
    reqs = _one_bucket_stream(16, seed=3)
    ref = {r.rid: r for r in
           ServingEngine(max_batch=4, max_wait_ms=1e9, pipeline_depth=0,
                         clock=FrozenClock()).serve_stream(reqs)}
    router = _router()
    got = router.serve_stream(reqs)
    assert len(got) == len(ref)
    for g in got:
        np.testing.assert_array_equal(g.perm, ref[g.rid].perm)
        np.testing.assert_array_equal(g.exposure, ref[g.rid].exposure)
        assert g.utility == ref[g.rid].utility
    router.close()


def test_suspect_primary_hedges_and_dedupes_by_rid():
    reqs = _one_bucket_stream(8, seed=1)
    router = _router()
    router.warmup(reqs)
    bucket = router._bucket_key(reqs[0])
    primary = router._owners(bucket)[0]
    router.replicas[primary].health.observe_lag(1e9)  # wedged, not dead
    router.tick()
    assert router.replicas[primary].health.state == SUSPECT
    res = []
    for r in reqs:
        res += router.submit(r)
    res += router.drain()
    assert sorted(r.rid for r in res) == list(range(8))
    s = router.fleet_summary()
    assert s["hedges"] == 8                     # every submit hedged
    assert s["served"] == 8 and s["lost"] == 0
    # both copies completed: one settled each future, one deduped
    assert s["duplicates_deduped"] == 8
    assert s["hedge_wins"] == 8
    assert s["orphaned_futures"] == 0
    router.close()


def test_hedging_disabled_never_duplicates():
    reqs = _one_bucket_stream(8, seed=1)
    router = _router(hedging=False)
    router.warmup(reqs)
    primary = router._owners(router._bucket_key(reqs[0]))[0]
    router.replicas[primary].health.observe_lag(1e9)
    router.tick()
    res = router.serve_stream(reqs, warmup=False)
    s = router.fleet_summary()
    assert sorted(r.rid for r in res) == list(range(8))
    assert s["hedges"] == 0 and s["duplicates_deduped"] == 0
    router.close()


def test_crash_fails_over_and_restarts_with_zero_lost():
    reqs = _one_bucket_stream(32, seed=2)
    bucket_probe = _router()
    primary_name = bucket_probe.replicas[
        bucket_probe._owners(bucket_probe._bucket_key(reqs[0]))[0]].name
    bucket_probe.close()
    plan = FaultPlan(replicas={
        primary_name: ReplicaFaults(crash_at_batch=2)})
    router = _router(fault_plan=plan)
    res = router.serve_stream(reqs)
    assert sorted(r.rid for r in res) == list(range(32))
    s = router.fleet_summary()
    assert s["crashes"] == 1 and s["restarts"] == 1
    assert s["failovers"] >= 1 and s["retries"] >= 1
    assert s["lost"] == 0 and s["orphaned_futures"] == 0
    rep = next(r for r in router.replicas if r.name == primary_name)
    assert rep.health.state == HEALTHY          # restarted + recovered
    assert [t[1:3] for t in rep.health.transitions] == [
        (HEALTHY, DEAD), (DEAD, RECOVERING), (RECOVERING, HEALTHY)]
    # no recompiles outside warmup, on any incarnation
    for r in router.replicas:
        assert r.engine.metrics.compiles_post_warmup == 0
    router.close()


def test_drain_kill_hands_queued_requests_off():
    reqs = _one_bucket_stream(10, seed=4)       # 2 full + 1 partial batch
    probe = _router()
    primary_name = probe.replicas[
        probe._owners(probe._bucket_key(reqs[0]))[0]].name
    probe.close()
    plan = FaultPlan(replicas={
        primary_name: ReplicaFaults(kill_during_drain=True)})
    router = _router(fault_plan=plan)
    res = router.serve_stream(reqs)
    assert sorted(r.rid for r in res) == list(range(10))
    s = router.fleet_summary()
    assert s["crashes"] == 1                    # the drain kill
    assert s["lost"] == 0 and s["orphaned_futures"] == 0
    router.close()


def test_rid_collision_rejected_while_in_flight():
    router = _router()
    reqs = _one_bucket_stream(2, seed=5)
    reqs[1].rid = reqs[0].rid
    router.warmup(reqs)
    router.submit_future(reqs[0])
    with pytest.raises(ValueError, match="already in flight"):
        router.submit_future(reqs[1])
    router.drain()
    router.close()


# ---------------------------------------------------------------------------
# Checkpoint/restore through the fleet (last-good λ̂, not cold)
# ---------------------------------------------------------------------------


def _cov_stream(n, seed=0):
    return make_drift_stream(DriftSpec(kind="none"), tag=TAG, n_requests=n,
                             m1=96, m2=8, K=K, d_cov=D_COV, b_frac=0.25,
                             seed=seed)


def _lane_factory(tmp_path):
    rng = np.random.default_rng(0)
    X = rng.normal(size=(48, D_COV)).astype(np.float32)
    lam = np.abs(rng.normal(size=(48, K))).astype(np.float32)

    def factory(name):
        eng = ServingEngine(max_batch=4, max_wait_ms=1e9, pipeline_depth=1,
                            clock=FrozenClock())
        eng.register_predictor(TAG, MeanLambdaPredictor.fit(X, lam),
                               d_cov=D_COV)
        store = CheckpointStore(str(tmp_path / f"ckpt-{name}"), keep_last=3)
        lane = RefreshLane(eng, min_samples=4, checkpoint=store)
        return eng, lane
    return factory


def test_restart_resumes_at_last_good_epoch(tmp_path):
    """The tentpole's checkpoint/restore contract end-to-end: refresh
    publishes epoch 1 (checkpointed by the lane), the primary crashes,
    and its restarted incarnation serves epoch 1 — resumed from the
    epoch checkpoint, not cold at 0."""
    reqs = _cov_stream(32)
    probe = _router(_lane_factory(tmp_path / "probe"))
    primary = probe.replicas[
        probe._owners(probe._bucket_key(reqs[0]))[0]].name
    probe.close()

    plan = FaultPlan(replicas={primary: ReplicaFaults(crash_at_batch=3)})
    router = _router(_lane_factory(tmp_path / "fleet"), fault_plan=plan)
    router.warmup(reqs)
    res = []
    for r in reqs[:12]:                         # 3 batches, all pre-crash
        res += router.submit(r)
        router.tick()
    rep_reports = router.refresh()
    assert rep_reports[primary][TAG]["swapped"]
    assert rep_reports[primary][TAG]["checkpointed"]
    for r in reqs[12:]:                         # crash lands in here
        res += router.submit(r)
        res += router.poll()
        router.tick()
    res += router.drain()
    assert sorted(r.rid for r in res) == list(range(32))

    rep = next(r for r in router.replicas if r.name == primary)
    assert rep.restore_history == [{TAG: 1}]    # restored epoch 1 exactly
    assert rep.engine.predictor_epoch(TAG) == 1
    assert rep.store.predictor_epochs(TAG) == [1]

    # the restored primary serves epoch 1 now
    post = router.serve_stream(_cov_stream(8, seed=9), warmup=False)
    assert any(r.epoch == 1 for r in post)
    assert router.fleet_summary()["lost"] == 0
    router.close()


def test_poisoned_swap_refused_fleet_keeps_serving(tmp_path):
    reqs = _cov_stream(16)
    probe = _router(_lane_factory(tmp_path / "probe"))
    primary = probe.replicas[
        probe._owners(probe._bucket_key(reqs[0]))[0]].name
    probe.close()
    plan = FaultPlan(replicas={primary: ReplicaFaults(poison_swap_at=0)})
    router = _router(_lane_factory(tmp_path / "fleet"), fault_plan=plan)
    router.warmup(reqs)
    res = []
    for r in reqs:
        res += router.submit(r)
        router.tick()
    res += router.drain()
    report = router.refresh()[primary][TAG]
    assert not report["swapped"] and "refused" in report["reason"]
    rep = next(r for r in router.replicas if r.name == primary)
    assert rep.engine.metrics.refresh_failures == 1
    assert rep.engine.predictor_epoch(TAG) == 0     # still last-good
    assert rep.store.predictor_epochs(TAG) == []    # poison never persisted
    post = router.serve_stream(_cov_stream(8, seed=9), warmup=False)
    assert sorted(r.rid for r in post) == list(range(8))
    router.close()


# ---------------------------------------------------------------------------
# The full seeded chaos acceptance run (the PR's headline assertion)
# ---------------------------------------------------------------------------


def _chaos_name_order(reqs):
    """Order replica names for FaultPlan.chaos so names[0] (crash) is
    the primary of the first bucket and names[1] (blackhole) the
    primary of the second if distinct — the faults land on replicas
    that actually serve traffic, whatever the ring assigns."""
    probe = _router()
    keys = []
    for r in reqs:
        k = probe._bucket_key(r)
        if k not in keys:
            keys.append(k)
    prims = [probe.replicas[probe._owners(k)[0]].name for k in keys]
    probe.close()
    order = list(dict.fromkeys(prims))
    order += [r.name for r in probe.replicas if r.name not in order]
    return order


def test_chaos_plan_512_request_stream_loses_nothing():
    """3-replica fleet, 512-request mixed stream, the full canonical
    chaos plan (crash + blackhole + slow replica + drain kill): every
    request is served exactly once (hedged duplicates deduped by rid),
    zero futures orphaned, zero requests lost, the crashed replica is
    restarted, and no incarnation ever recompiles outside warmup."""
    mix = (Scenario("f", m1=64, m2=8, K=4, m1_jitter=0.0, weight=2.0,
                    surface="feed"),
           Scenario("s", m1=96, m2=16, K=4, m1_jitter=0.0, weight=1.0,
                    surface="search"))
    reqs = make_stream(mix, n_requests=512, seed=11)
    order = _chaos_name_order(reqs)
    plan = FaultPlan.chaos(order, seed=11, slow_ms=0.2)
    router = _router(
        fault_plan=plan,
        health=HealthConfig(suspect_after_s=0.002, dead_after_s=10.0,
                            lag_suspect_ms=1e9))
    res = router.serve_stream(reqs)
    served = [r for r in res if not isinstance(r, Shed)]
    assert sorted(r.rid for r in served) == list(range(512))
    assert len(set(r.rid for r in served)) == 512   # no duplicates served
    s = router.fleet_summary()
    assert s["orphaned_futures"] == 0
    assert s["lost"] == 0
    assert s["crashes"] >= 1 and s["restarts"] >= 1
    assert s["heartbeats_missed"] >= 1              # blackhole was real
    crashed = next(r for r in router.replicas if r.name == order[0])
    assert crashed.restore_history                  # supervised restart ran
    for rep in router.replicas:
        assert rep.engine.metrics.compiles_post_warmup == 0
    # accounting closes: every submission is served, shed, or lost
    assert s["submitted"] == s["served"] + s["sheds"] + s["lost"] == 512
    router.close()


def test_chaos_replay_is_deterministic():
    """Same seed, same stream -> same fault schedule and the same
    fleet-level failure accounting (the chaos harness's whole point)."""
    mix = (Scenario("f", m1=64, m2=8, K=4, m1_jitter=0.0),)
    reqs = make_stream(mix, n_requests=64, seed=3)
    order = _chaos_name_order(reqs)

    def run():
        plan = FaultPlan.chaos(order, seed=3, slow_ms=0.0)
        router = _router(fault_plan=plan)
        res = router.serve_stream(reqs)
        s = router.fleet_summary()
        router.close()
        keys = ("submitted", "served", "crashes", "restarts", "lost")
        transitions = [[t[1:3] for t in rep.health.transitions]
                       for rep in router.replicas]
        return {k: s[k] for k in keys}, transitions, sorted(
            r.rid for r in res)

    assert run() == run()
