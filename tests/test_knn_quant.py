"""The quantized KNN db sweep (int8 / bf16 packed slabs, exact f32
survivor re-score) vs its oracles, end to end:

  * selection parity: knn_quant_scan (XLA scan twin) and the Pallas
    quantized kernels vs kernels.ref.knn_quant_select_ref /
    knn_quant_lambda_ref — BITWISE on the selected neighbour set and
    the margin-guard flags, exact-on-x̃ by construction, at slab sizes
    that do and do not divide n_train;
  * the full-RankingOutput contract: ops.predict_rank_audited on a
    quantized predictor vs the COMPILED f32 oracle — the parity target
    the paper's serving path actually guarantees (perm / utility /
    exposure / compliant bitwise, λ̂ to 1-ulp einsum-layout tolerance);
  * adversarial near-ties planted inside the quantization error fire
    the margin guard (observability for forced fallbacks);
  * degenerate all-identical db rows: every distance ties, selection
    must collapse to the lowest global indices, bitwise vs the oracle;
  * a lossless-grid db (values on the 0.5 grid, absmax planted per
    slab): the int8 predictor's RankingOutput equals the f32
    predictor's bitwise INCLUDING λ̂;
  * refresh hygiene: quantized() state round-trips through
    state_fields/with_state, and unquantized predictors keep their
    2-key state.

The property layer (hypothesis, import-guarded like test_refresh.py)
pins the bitwise-selection invariant under random geometry: the
quantized sweep's survivor re-score selects the same neighbour set as
the full-precision-on-x̃ oracle, always.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.predictors import (
    KNNLambdaPredictor,
    knn_predict_quant,
    knn_quant_scan,
    pack_knn_db,
    predictor_state,
    state_fields,
    with_state,
)
from repro.kernels import ops, ref
from repro.kernels.common import PAD_Y2, QUANT_EXTRA, dequant_rows
from repro.kernels.knn_topk import knn_lambda_quant_pallas

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                              # pragma: no cover
    given = None

KEY = jax.random.key(31)
N_TRAIN, D, K = 600, 12, 4
FIELDS = ("perm", "utility", "exposure", "compliant")


def _db(n=N_TRAIN, d=D, salt=0):
    ks = jax.random.split(jax.random.fold_in(KEY, salt), 2)
    X_db = jax.random.normal(ks[0], (n, d), jnp.float32)
    lam_db = jnp.abs(jax.random.normal(ks[1], (n, K), jnp.float32))
    return X_db, lam_db


def _queries(b=16, d=D, salt=1):
    return jax.random.normal(jax.random.fold_in(KEY, 1000 + salt),
                             (b, d), jnp.float32)


def _rank_problem(n, m1, m2, salt=2):
    ks = jax.random.split(jax.random.fold_in(KEY, 2000 + salt), 4)
    u = jax.random.uniform(ks[0], (n, m1), minval=1.0, maxval=5.0)
    a = (jax.random.uniform(ks[1], (n, K, m1)) < 0.15).astype(jnp.float32)
    b = 0.1 * jnp.abs(jax.random.normal(ks[2], (n, K)))
    gamma = jnp.abs(jax.random.normal(ks[3], (n, m2)))
    return u, a, b, gamma


# ---------------------------------------------------------------------------
# Selection parity: scan twin and kernel vs the quantized oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["int8", "bf16"])
@pytest.mark.parametrize("slab", [200, 512])     # divides / pads N_TRAIN
def test_quant_scan_matches_oracle_bitwise(mode, slab):
    X_db, _ = _db()
    Xq = _queries()
    X_q, q_scale, y2_q = pack_knn_db(X_db, mode=mode, slab=slab)
    d2, idx, guard = knn_quant_scan(X_q, q_scale, y2_q, Xq, k=5, mode=mode)
    d2_r, idx_r, guard_r = ref.knn_quant_select_ref(
        Xq, X_q, q_scale, y2_q, 5, mode=mode)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx_r))
    np.testing.assert_array_equal(np.asarray(d2), np.asarray(d2_r))
    np.testing.assert_array_equal(np.asarray(guard), np.asarray(guard_r))


@pytest.mark.parametrize("mode", ["int8", "bf16"])
@pytest.mark.parametrize("slab", [200, 512])
def test_quant_kernel_lambda_matches_oracle(mode, slab):
    X_db, lam_db = _db()
    Xq = _queries()
    X_q, q_scale, y2_q = pack_knn_db(X_db, mode=mode, slab=slab)
    lam_pad = jnp.pad(lam_db, ((0, X_q.shape[0] - lam_db.shape[0]), (0, 0)))
    lam, guard = knn_lambda_quant_pallas(
        Xq, X_q, q_scale, y2_q, lam_pad, k=5, mode=mode,
        tile_q=8, tile_n=slab, interpret=True)
    lam_r, guard_r = ref.knn_quant_lambda_ref(
        Xq, X_q, q_scale, y2_q, lam_db, 5, mode=mode)
    # λ̂ to 1-ulp: the kernel's per-slab accumulation and the oracle's
    # one-shot einsum differ in reduction layout, nothing else
    np.testing.assert_allclose(np.asarray(lam), np.asarray(lam_r),
                               rtol=2e-7, atol=2e-7)
    np.testing.assert_array_equal(np.asarray(guard), np.asarray(guard_r))


def test_quant_pad_rows_never_selected():
    """slab=512 pads 600 db rows to 1024: the 424 phantom rows carry
    PAD_Y2 and must never enter any top-k."""
    X_db, _ = _db()
    X_q, q_scale, y2_q = pack_knn_db(X_db, mode="int8", slab=512)
    assert X_q.shape[0] == 1024
    assert np.asarray(y2_q)[N_TRAIN:].min() == np.float32(PAD_Y2)
    _, idx, _ = knn_quant_scan(X_q, q_scale, y2_q, _queries(b=32),
                               k=5 + QUANT_EXTRA - 1, mode="int8")
    assert int(np.asarray(idx).max()) < N_TRAIN


# ---------------------------------------------------------------------------
# Full-RankingOutput contract through the serving dispatcher
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["int8", "bf16"])
def test_predict_rank_audited_quant_parity(mode):
    X_db, lam_db = _db()
    base = KNNLambdaPredictor.fit(np.asarray(X_db), np.asarray(lam_db), k=5)
    pred = base.quantized(mode=mode, slab=200)
    n, m1, m2 = 16, 96, 8
    X = _queries(b=n)
    u, a, b, gamma = _rank_problem(n, m1, m2)
    got = ops.predict_rank_audited(X, pred, u, a, b, gamma, m2=m2)
    # the oracle under jit: eager jnp.sum reduces in a different order
    # than the compiled audit (1 ulp in utility); the serving contract
    # is vs the compiled program
    want = jax.jit(lambda *t: ref.predict_rank_audited_ref(
        *t[:1], pred, *t[1:], m2))(X, u, a, b, gamma)
    w = dict(zip(("vals", "perm", "utility", "exposure", "compliant",
                  "lam"), want))
    for f in FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(got, f)), np.asarray(w[f]), err_msg=f)
    np.testing.assert_allclose(np.asarray(got.lam), np.asarray(w["lam"]),
                               rtol=2e-7, atol=2e-7)


def test_lossless_grid_int8_equals_f32_bitwise():
    """Values on the 0.5 grid with the absmax planted per slab make
    every slab scale exactly 0.5 — int8 reconstructs the db bitwise,
    so the quantized RankingOutput (λ̂ included) must equal f32's."""
    rng = np.random.default_rng(5)
    X_ll = np.clip(np.round(rng.uniform(-63.0, 63.0, (N_TRAIN, D)) * 2.0)
                   / 2.0, -63.5, 63.5).astype(np.float32)
    X_ll[::200] = 63.5
    lam_db = np.abs(rng.normal(size=(N_TRAIN, K))).astype(np.float32)
    base = KNNLambdaPredictor.fit(X_ll, lam_db, k=5)
    quant = base.quantized(mode="int8", slab=200)
    got_db = dequant_rows(quant.X_q[:N_TRAIN],
                          jnp.repeat(quant.q_scale[:, 0], 200)[:N_TRAIN,
                                                               None])
    np.testing.assert_array_equal(np.asarray(got_db), X_ll)
    n, m1, m2 = 16, 96, 8
    X = jnp.asarray(np.round(rng.uniform(-10, 10, (n, D)) * 2.0)
                    .astype(np.float32) / 2.0)
    u, a, b, gamma = _rank_problem(n, m1, m2, salt=6)
    o32 = ops.predict_rank_audited(X, base, u, a, b, gamma, m2=m2)
    oq = ops.predict_rank_audited(X, quant, u, a, b, gamma, m2=m2)
    for f in FIELDS + ("lam",):
        np.testing.assert_array_equal(
            np.asarray(getattr(o32, f)), np.asarray(getattr(oq, f)),
            err_msg=f)


# ---------------------------------------------------------------------------
# Guard observability: forced fallbacks and degenerate geometry
# ---------------------------------------------------------------------------


def test_adversarial_near_tie_fires_guard():
    """Rows k-1 and k planted closer together than the query's
    quantization error: the margin guard MUST flag those queries (the
    exact re-score already served the right answer — the guard is the
    observability signal the fleet alarms on)."""
    rng = np.random.default_rng(11)
    X_db = rng.normal(size=(N_TRAIN, D)).astype(np.float32) * 40.0
    q = rng.normal(size=(D,)).astype(np.float32) * 40.0
    # plant a shell of rows at nearly identical distance from q
    for i in range(8):
        v = rng.normal(size=(D,)).astype(np.float32)
        v /= np.linalg.norm(v)
        X_db[i] = q + v * (1.0 + 1e-4 * i)
    lam_db = np.abs(rng.normal(size=(N_TRAIN, K))).astype(np.float32)
    X_q, q_scale, y2_q = pack_knn_db(jnp.asarray(X_db), mode="int8",
                                     slab=200)
    Xq = jnp.asarray(np.repeat(q[None, :], 8, axis=0))
    _, _, guard = knn_quant_scan(X_q, q_scale, y2_q, Xq, k=5, mode="int8")
    assert int(np.asarray(guard).sum()) >= 1
    # and the flagged selection still matches the exact-on-x̃ oracle
    _, idx, _ = knn_quant_scan(X_q, q_scale, y2_q, Xq, k=5, mode="int8")
    _, idx_r, _ = ref.knn_quant_select_ref(Xq, X_q, q_scale, y2_q, 5,
                                           mode="int8")
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx_r))


def test_all_identical_rows_select_lowest_indices():
    """Every db row identical -> every distance ties -> the selection
    must collapse to [0..k-1] (ties to the lowest global index), and
    the guard fires on the all-tied boundary."""
    X_db = jnp.ones((256, D), jnp.float32) * 3.0
    X_q, q_scale, y2_q = pack_knn_db(X_db, mode="int8", slab=64)
    Xq = _queries(b=8, salt=9)
    d2, idx, guard = knn_quant_scan(X_q, q_scale, y2_q, Xq, k=5,
                                    mode="int8")
    np.testing.assert_array_equal(
        np.asarray(idx), np.broadcast_to(np.arange(5), (8, 5)))
    d2_r, idx_r, guard_r = ref.knn_quant_select_ref(
        Xq, X_q, q_scale, y2_q, 5, mode="int8")
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx_r))
    np.testing.assert_array_equal(np.asarray(d2), np.asarray(d2_r))
    np.testing.assert_array_equal(np.asarray(guard), np.asarray(guard_r))
    assert int(np.asarray(guard).sum()) == 8   # gap 0 <= any error bound


# ---------------------------------------------------------------------------
# Refresh/state hygiene for the packed representation
# ---------------------------------------------------------------------------


def test_quantized_state_roundtrip_and_unquantized_stays_2key():
    X_db, lam_db = _db()
    base = KNNLambdaPredictor.fit(np.asarray(X_db), np.asarray(lam_db),
                                  k=5)
    assert state_fields(base) == ("X_db", "lam_db")
    quant = base.quantized(mode="int8", slab=200)
    assert set(state_fields(quant)) == {"X_db", "lam_db", "X_q",
                                        "q_scale", "y2_q"}
    st_ = predictor_state(quant)
    back = with_state(quant, st_)
    lam_a = np.asarray(knn_predict_quant(
        quant.X_q, quant.q_scale, quant.y2_q, quant.lam_db, _queries(),
        k=5, mode="int8"))
    lam_b = np.asarray(knn_predict_quant(
        back.X_q, back.q_scale, back.y2_q, back.lam_db, _queries(),
        k=5, mode="int8"))
    np.testing.assert_array_equal(lam_a, lam_b)


# ---------------------------------------------------------------------------
# Property layer (hypothesis; skipped visibly when unavailable)
# ---------------------------------------------------------------------------


if given is not None:
    settings.register_profile("ci_quant", max_examples=25, deadline=None)
    settings.load_profile("ci_quant")

    @given(st.integers(0, 10 ** 6), st.sampled_from([64, 100]),
           st.sampled_from(["int8", "bf16"]))
    def test_quant_selection_bitwise_invariant(seed, slab, mode):
        """THE invariant the tentpole rests on: for any db/query draw,
        the quantized sweep + exact f32 survivor re-score selects the
        same neighbour set, in the same order, as the full-precision
        oracle on the dequantized db x̃ — bitwise, including guard."""
        rng = np.random.default_rng(seed)
        n = int(rng.integers(40, 200))
        X_db = jnp.asarray(rng.normal(size=(n, 8)).astype(np.float32)
                           * rng.uniform(0.1, 30.0))
        Xq = jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32))
        X_q, q_scale, y2_q = pack_knn_db(X_db, mode=mode, slab=slab)
        d2, idx, guard = knn_quant_scan(X_q, q_scale, y2_q, Xq, k=5,
                                        mode=mode)
        d2_r, idx_r, guard_r = ref.knn_quant_select_ref(
            Xq, X_q, q_scale, y2_q, 5, mode=mode)
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx_r))
        np.testing.assert_array_equal(np.asarray(d2), np.asarray(d2_r))
        np.testing.assert_array_equal(np.asarray(guard),
                                      np.asarray(guard_r))
else:                                            # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed — property layer "
                             "runs in CI (pip install .[dev])")
    def test_quant_property_layer_requires_hypothesis():
        pytest.importorskip("hypothesis")
