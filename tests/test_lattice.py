"""Adaptive bucket lattice: histogram telemetry, optimizer invariants,
trough-gated shadow re-warm, and the epoch-fenced swap.

The deterministic layer proves the swap discipline end to end: at
pipeline depths 0-2 a hot engine that learns corners mid-stream serves
every epoch bitwise-equal to a COLD engine constructed directly on that
epoch's lattice, a poisoned proposal rolls back without pausing the
stream, and no compile ever lands on the dispatch path. The property
layer (hypothesis, import-guarded like test_refresh.py) proves the
optimizer invariants — coverage, budget, monotone-vs-pow2 — with
deterministic twins so the invariants hold even where hypothesis is not
installed.
"""

import os

import numpy as np
import pytest

from repro.serving import (
    LAM_TAG,
    FleetRouter,
    Lattice,
    LatticeLane,
    Scenario,
    ServingEngine,
    ShapeHistogram,
    StagingRing,
    TroughDetector,
    bucket_for,
    geometry_key,
    make_stream,
    optimize_lattice,
    padding_waste,
    resolve_autotune,
)
from repro.serving.buckets import PAGE
from repro.serving.lattice import expected_padded_work, padded_work

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                              # pragma: no cover
    given = None


# ---------------------------------------------------------------------------
# Lattice routing
# ---------------------------------------------------------------------------


def test_pow2_default_matches_bucket_for():
    lat = Lattice()
    assert not lat.adaptive
    for m1, m2, K in ((100, 10, 3), (500, 50, 5), (1100, 12, 17)):
        got = lat.bucket_for(m1=m1, m2=m2, K=K, tag=LAM_TAG, batch=8)
        assert got == bucket_for(m1=m1, m2=m2, K=K, tag=LAM_TAG, batch=8)


def test_validate_rejects_malformed_corners():
    with pytest.raises(ValueError, match="zero corners"):
        Lattice(corners=()).validate()
    with pytest.raises(ValueError, match="need"):
        Lattice(corners=((128, 8),)).validate()
    with pytest.raises(ValueError, match="non-positive"):
        Lattice(corners=((128, 0, 4),)).validate()
    with pytest.raises(ValueError, match="m2 > m1"):
        Lattice(corners=((64, 128, 4),)).validate()
    Lattice(corners=((128, 8, 4),)).validate()   # well-posed: no raise


def test_covering_corner_picks_cheapest_cover():
    lat = Lattice(corners=((1024, 16, 4), (192, 8, 4), (320, 8, 8)))
    assert lat.covering_corner(150, 8, 3) == (192, 8, 4)
    assert lat.covering_corner(300, 8, 7) == (320, 8, 8)
    assert lat.covering_corner(300, 12, 3) == (1024, 16, 4)
    assert lat.covering_corner(2000, 8, 3) is None


def test_out_of_lattice_falls_back_to_pow2():
    lat = Lattice(corners=((192, 8, 4),))
    inside = lat.bucket_for(m1=150, m2=8, K=3, tag=LAM_TAG, batch=4)
    assert (inside.m1, inside.m2, inside.K) == (192, 8, 4)
    outside = lat.bucket_for(m1=700, m2=8, K=3, tag=LAM_TAG, batch=4)
    assert outside == bucket_for(m1=700, m2=8, K=3, tag=LAM_TAG, batch=4)
    with pytest.raises(ValueError, match="m2 <= m1"):
        lat.bucket_for(m1=8, m2=9, K=3, tag=LAM_TAG, batch=4)


# ---------------------------------------------------------------------------
# Shape histogram
# ---------------------------------------------------------------------------


def test_histogram_counts_and_geometry_aggregation():
    h = ShapeHistogram()
    h.observe(tag=LAM_TAG, m1=150, m2=8, K=3, surface="feed")
    h.observe(tag=LAM_TAG, m1=150, m2=8, K=3, surface="feed")
    h.observe(tag="arch", m1=150, m2=8, K=3, d_cov=16, surface="strip")
    h.observe(tag=LAM_TAG, m1=300, m2=8, K=5)
    assert h.total == 4 and len(h) == 3
    w = h.geometry_weights()
    # same (m1, m2, K) aggregates across tags and surfaces
    assert set(w) == {(150, 8, 3), (300, 8, 5)}
    assert w[(150, 8, 3)] > w[(300, 8, 5)]


def test_histogram_is_deterministic_and_decays():
    def feed(h):
        for i in range(50):
            h.observe(tag=LAM_TAG, m1=100 + i % 3, m2=8, K=3)
    a, b = ShapeHistogram(decay=0.9), ShapeHistogram(decay=0.9)
    feed(a)
    feed(b)
    assert a.snapshot() == b.snapshot()          # replayable bit-for-bit
    # an old cell's weight decays relative to a fresh equal-count cell
    h = ShapeHistogram(decay=0.5)
    h.observe(tag=LAM_TAG, m1=100, m2=8, K=3)
    for _ in range(10):
        h.observe(tag=LAM_TAG, m1=200, m2=8, K=3)
    w = h.geometry_weights()
    assert w[(100, 8, 3)] < 0.01 < w[(200, 8, 3)]


def test_histogram_save_load_roundtrip(tmp_path):
    h = ShapeHistogram(decay=0.99)
    h.observe(tag=LAM_TAG, m1=150, m2=8, K=3, surface="feed")
    h.observe(tag="arch", m1=300, m2=16, K=5, d_cov=12)
    path = str(tmp_path / "hist.json")
    h.save(path)
    h2 = ShapeHistogram.load(path)
    assert h2.snapshot() == h.snapshot()
    assert h2.shapes() == h.shapes()
    assert ShapeHistogram.load(str(tmp_path / "missing.json")).total == 0


# ---------------------------------------------------------------------------
# Optimizer invariants (deterministic twins of the property layer)
# ---------------------------------------------------------------------------


def _random_weights(rng, n):
    return {(int(rng.integers(8, 2000)),
             int(rng.integers(1, 65)),
             int(rng.integers(1, 33))): float(rng.uniform(0.1, 10.0))
            for _ in range(n)}


def _well_posed(weights):
    return {(m1, min(m2, m1), K): w for (m1, m2, K), w in weights.items()}


def _check_invariants(weights, lat, max_executables):
    lat.validate()
    assert len(lat.corners) <= max_executables
    for m1, m2, K in weights:                    # coverage: no fallback
        assert lat.covering_corner(m1, m2, K) is not None, (m1, m2, K)
    pow2_groups = {bucket_for(m1=m1, m2=m2, K=K, tag="_", batch=1)
                   for m1, m2, K in weights}
    if len(pow2_groups) <= max_executables:      # monotone vs pow2
        assert (expected_padded_work(lat, weights)
                <= expected_padded_work(Lattice(), weights) + 1e-6)


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("budget", (1, 4, 16))
def test_optimizer_invariants(seed, budget):
    rng = np.random.default_rng(seed)
    weights = _well_posed(_random_weights(rng, 12))
    lat = optimize_lattice(weights, max_executables=budget)
    _check_invariants(weights, lat, budget)


def test_optimizer_empty_histogram_is_pow2():
    assert optimize_lattice(ShapeHistogram()).corners is None
    assert optimize_lattice({}).corners is None
    with pytest.raises(ValueError, match=">= 1"):
        optimize_lattice({(128, 8, 4): 1.0}, max_executables=0)


def test_optimizer_batch_cost_suppresses_fragmentation():
    # one tight traffic cluster: with batch-aware costing a split must
    # buy more routing work than the half-batch of padding it adds, so
    # the cluster stays ONE corner; the batch-blind objective may
    # shatter it across the budget
    weights = {(600 + 8 * i, 10, 3): 1.0 for i in range(8)}
    lat_b8 = optimize_lattice(weights, max_executables=8, batch=8)
    lat_b1 = optimize_lattice(weights, max_executables=8, batch=1)
    assert len(lat_b8.corners) <= len(lat_b1.corners)
    assert len(lat_b8.corners) == 1
    _check_invariants(weights, lat_b8, 8)


def test_padding_waste_accounting():
    weights = {(540, 10, 3): 4.0, (300, 8, 5): 2.0}
    pow2_waste = padding_waste(Lattice(), weights)
    adaptive = optimize_lattice(weights, max_executables=4)
    assert padding_waste(adaptive, weights) < pow2_waste
    assert padding_waste(adaptive, weights) >= 1.0
    assert np.isnan(padding_waste(Lattice(), {}))
    # the analytic model itself: rank + audit cells, db bytes amortized
    assert padded_work(100, 10, 3) == 100 * 10 + 3 * 100
    assert (padded_work(100, 10, 3, d_cov=16, n_db=1000, batch=8)
            == 100 * 10 + 3 * 100 + 1000 * 16 * 4 / 8)


# ---------------------------------------------------------------------------
# Trough detector
# ---------------------------------------------------------------------------


def test_trough_requires_quiet_for_patience_window():
    det = TroughDetector(rate_threshold_qps=10.0, patience_s=1.0)
    t = 0.0
    for _ in range(50):                          # busy: 1000 qps
        det.observe_arrival(t)
        t += 0.001
    assert not det.in_trough(t)
    assert not det.in_trough(t + 0.5)            # quiet, patience not met
    assert det.in_trough(t + 2.0)                # quiet past patience
    t += 2.1                                     # traffic resumes: the
    for _ in range(30):                          # rate EWMA recovers and
        det.observe_arrival(t)                   # the trough closes
        t += 0.001
    assert not det.in_trough(t)


def test_backlogged_engine_is_never_in_trough():
    det = TroughDetector(rate_threshold_qps=10.0, lag_threshold_ms=5.0,
                         patience_s=0.1)
    det.observe_arrival(0.0)
    for _ in range(20):
        det.observe_lag(50.0)                    # admission lag: backed up
    assert not det.in_trough(10.0)               # arrivals quiet, lag is not


# ---------------------------------------------------------------------------
# Epoch-fenced swap: hot engine == cold engine, per epoch, bitwise
# ---------------------------------------------------------------------------

MIX = (
    Scenario("feed", m1=150, m2=8, K=3, weight=2.0, m1_jitter=0.1,
             surface="feed"),
    Scenario("strip", m1=300, m2=8, K=5, weight=1.0, m1_jitter=0.1,
             surface="strip"),
)


def _engine(depth, lattice=None):
    # max_wait_ms=1e9 kills the deadline flush: batch composition is a
    # pure function of the stream, so hot and cold runs are comparable
    return ServingEngine(max_batch=4, max_wait_ms=1e9,
                         pipeline_depth=depth, lattice=lattice)


def _bitwise(a, b):
    return (np.array_equal(a.perm, b.perm)
            and a.utility == b.utility
            and np.array_equal(a.exposure, b.exposure)
            and a.compliant == b.compliant)


@pytest.mark.parametrize("depth", (0, 1, 2))
def test_swap_serves_bitwise_equal_to_cold_engine(depth):
    c0 = make_stream(MIX, n_requests=16, seed=1)
    c1 = make_stream(MIX, n_requests=16, seed=2)
    for i, r in enumerate(c1):
        r.rid = 1000 + i
    eng = _engine(depth)
    lane = LatticeLane(eng, max_executables=4)
    eng.warmup(c0 + c1)
    got0 = eng.serve_stream(c0, warmup=False)
    rep = lane.rewarm()
    assert rep["swapped"] and rep["epoch"] == 1
    assert eng.lattice().adaptive
    got1 = eng.serve_stream(c1, warmup=False)
    assert {r.lattice_epoch for r in got0} == {0}
    assert {r.lattice_epoch for r in got1} == {1}
    assert eng.metrics.compiles_post_warmup == 0
    assert eng.metrics.shadow_compiles >= 1
    assert all(v == 1 for v in eng.jit_cache_sizes().values())
    # each epoch bitwise vs a cold engine built on that epoch's lattice
    for lattice, reqs, got in ((Lattice(), c0, got0),
                               (eng.lattice(), c1, got1)):
        cold = _engine(depth, lattice=lattice)
        ref = {r.rid: r for r in cold.serve_stream(reqs)}
        assert all(_bitwise(r, ref[r.rid]) for r in got)
        cold.close()
    eng.close()


def test_swap_without_shadow_warm_refuses():
    reqs = make_stream(MIX, n_requests=8, seed=3)
    eng = _engine(0)
    eng.serve_stream(reqs)
    with pytest.raises(ValueError, match="shadow_warm_lattice first"):
        eng.swap_lattice(Lattice(corners=((192, 8, 4), (320, 8, 8))))
    assert eng.lattice_epoch() == 0              # nothing flipped
    eng.close()


def test_swap_epochs_are_monotone():
    reqs = make_stream(MIX, n_requests=8, seed=4)
    eng = _engine(0)
    lane = LatticeLane(eng)
    eng.serve_stream(reqs)
    assert lane.rewarm()["swapped"]
    with pytest.raises(ValueError, match="monotone"):
        eng.swap_lattice(eng.lattice(), epoch=0)
    eng.close()


def test_failed_proposal_rolls_back_and_stream_continues():
    c0 = make_stream(MIX, n_requests=12, seed=5)
    c1 = make_stream(MIX, n_requests=12, seed=6)
    for i, r in enumerate(c1):
        r.rid = 2000 + i
    eng = _engine(1)
    lane = LatticeLane(eng)
    eng.warmup(c0 + c1)
    eng.serve_stream(c0, warmup=False)
    lane.propose = lambda: Lattice(corners=((64, 128, 4),))  # m2 > m1
    rep = lane.rewarm()
    del lane.propose
    assert not rep["swapped"] and "rewarm-failed" in rep["reason"]
    assert eng.lattice_epoch() == 0              # last-good kept
    assert eng.metrics.lattice_rollbacks == 1
    got = eng.serve_stream(c1, warmup=False)     # stream uninterrupted
    assert len(got) == len(c1)
    assert eng.metrics.compiles_post_warmup == 0
    eng.close()


def test_lane_skips_without_new_samples_or_changes():
    eng = _engine(0)
    lane = LatticeLane(eng, min_samples=4)
    assert lane.maybe_rewarm(0.0)["reason"] == "too-few-samples"
    assert lane.rewarm()["reason"] == "no-change"  # empty hist -> pow2
    eng.close()


def test_lane_saves_histogram_beside_autotune_table(tmp_path):
    path = str(tmp_path / "hist.json")
    reqs = make_stream(MIX, n_requests=8, seed=7)
    eng = _engine(0)
    lane = LatticeLane(eng, histogram_path=path)
    eng.serve_stream(reqs)
    assert lane.rewarm()["swapped"]
    assert os.path.exists(path)
    assert ShapeHistogram.load(path).total == len(reqs)
    eng.close()


# ---------------------------------------------------------------------------
# Pinned staging ring
# ---------------------------------------------------------------------------


def test_staging_ring_pins_page_aligned_buffers():
    bucket = bucket_for(m1=150, m2=8, K=3, tag=LAM_TAG, batch=4)
    ring = StagingRing(bucket, d_cov=None, depth=2)
    assert ring.allocated == 2
    seen = []
    for _ in range(6):                           # 3 full cycles
        staged = ring.acquire()
        for name in ("u", "a", "b", "gamma", "lam"):
            assert staged[name].ctypes.data % PAGE == 0, name
        seen.append(id(staged))
        ring.release(staged)
    assert ring.allocated == 2                   # nothing new allocated
    assert ring.reuses == 4                      # 6 acquires - 2 firsts
    assert set(seen) <= ring._owned
    with pytest.raises(AssertionError, match="never allocated"):
        ring.release({"u": np.zeros(1, np.float32)})


# ---------------------------------------------------------------------------
# Autotune geometry keys survive lattice swaps
# ---------------------------------------------------------------------------


def test_resolve_autotune_fallback_chain():
    b = bucket_for(m1=150, m2=8, K=3, tag=LAM_TAG, batch=4)
    exact = {geometry_key(b, d_cov=16): {"tile_b": 4, "tile_m": 128}}
    assert resolve_autotune(exact, b, d_cov=16)["tile_m"] == 128
    legacy = {geometry_key(b): {"tile_b": 4, "tile_m": 64}}
    assert resolve_autotune(legacy, b, d_cov=16)["tile_m"] == 64
    assert resolve_autotune({}, b) == {}


def test_resolve_autotune_nearest_cover_clamps_tiles():
    # tuned at the POW2 geometry; after a swap the adaptive corner is
    # smaller, so the tuned tiles must clamp to the new extents
    tuned = bucket_for(m1=150, m2=8, K=3, tag=LAM_TAG, batch=8)  # m1=256
    table = {geometry_key(tuned): {"tile_b": 8, "tile_m": 256,
                                   "tile_n": 512, "quant": "off"}}
    small = type(tuned)(tag=LAM_TAG, m1=192, m2=8, K=4, batch=8)
    got = resolve_autotune(table, small)
    assert got["tile_m"] == 192                  # clamped to the corner
    assert got["tile_n"] == 512 and got["quant"] == "off"
    # a cover must match the batch exactly and dominate every extent
    other_batch = type(tuned)(tag=LAM_TAG, m1=192, m2=8, K=4, batch=4)
    assert resolve_autotune(table, other_batch) == {}
    big = type(tuned)(tag=LAM_TAG, m1=512, m2=8, K=4, batch=8)
    assert resolve_autotune(table, big) == {}


def test_autotuned_tiles_survive_two_swaps():
    # tuned tiles apply to predictor-tagged buckets; LAM_TAG requests
    # carry λ inline and never resolve the table
    from repro.core.predictors import KNNLambdaPredictor

    d_cov = 16
    rng = np.random.default_rng(8)
    pred = KNNLambdaPredictor.fit(
        rng.normal(size=(64, d_cov)).astype(np.float32),
        np.abs(rng.normal(size=(64, 3))).astype(np.float32), k=5)
    reqs = make_stream((Scenario("s", m1=150, m2=8, K=3, tag="arch",
                                 d_cov=d_cov, m1_jitter=0.0),),
                       n_requests=8, seed=8)
    home = bucket_for(m1=150, m2=8, K=3, tag="arch", batch=4)
    table = {geometry_key(home, d_cov=d_cov):
             {"tile_b": 4, "tile_m": 128, "tile_n": 512, "quant": "off"}}
    eng = ServingEngine(max_batch=4, max_wait_ms=1e9, pipeline_depth=0,
                        autotune_table=table)
    eng.register_predictor("arch", pred, d_cov=d_cov)
    eng.serve_stream(reqs)
    tuned0 = eng.autotuned_buckets
    assert tuned0 >= 1
    # epoch 1: a smaller adaptive corner — nearest-cover keeps the
    # tiles (clamped). epoch 2: back to the tuned geometry — the bucket
    # is ALREADY warmed from epoch 0, so it is reused, not rebuilt.
    eng.rewarm_lattice(Lattice(corners=((192, 8, 4),)))
    eng.rewarm_lattice(Lattice(corners=((256, 8, 4),)))
    assert eng.lattice_epoch() == 2
    assert eng.autotuned_buckets == tuned0 + 1
    got = eng.serve_stream(reqs, warmup=False)
    assert len(got) == len(reqs)
    assert eng.metrics.compiles_post_warmup == 0
    eng.close()


# ---------------------------------------------------------------------------
# Metrics: padding-waste accounting and the lattice summary
# ---------------------------------------------------------------------------


def test_metrics_padding_and_lattice_summaries():
    reqs = make_stream(MIX, n_requests=12, seed=9)
    eng = _engine(0)
    lane = LatticeLane(eng)
    eng.serve_stream(reqs)
    s = eng.metrics.summary()
    assert s["padding"]["real_flops"] > 0
    assert s["padding"]["waste_flops"] >= 1.0
    assert s["lattice"]["lattice_swaps"] == 0
    assert lane.rewarm()["swapped"]
    s = eng.metrics.summary()["lattice"]
    assert s["lattice_swaps"] == 1 and s["lattice_rollbacks"] == 0
    assert s["shadow_compiles"] >= 1
    assert s["shadow_warm_ms"]["p50"] > 0
    eng.close()


# ---------------------------------------------------------------------------
# Fleet: one lattice generation fleet-wide, stable ownership
# ---------------------------------------------------------------------------


def _fleet_factory(name):
    return ServingEngine(max_batch=4, max_wait_ms=1e9, pipeline_depth=1)


def test_fleet_rewarm_flips_all_replicas_to_common_epoch():
    router = FleetRouter(_fleet_factory, 3,
                         heartbeat_interval_s=float("inf"))
    reqs = make_stream(MIX, n_requests=48, seed=10)
    got = router.serve_stream(reqs)
    assert len(got) == len(reqs)
    # aggregate the fleet's observed geometry and learn one lattice
    weights = {}
    for rep in router.replicas:
        for geom, w in rep.engine.shape_histogram.geometry_weights().items():
            weights[geom] = weights.get(geom, 0.0) + w
    new = optimize_lattice(weights, max_executables=4, batch=4)
    assert new.adaptive
    rep = router.rewarm_lattice(new)
    epochs = {r.engine.lattice_epoch() for r in router.replicas}
    assert epochs == {rep["epoch"]}              # ONE generation fleet-wide
    c1 = make_stream(MIX, n_requests=24, seed=11)
    for i, r in enumerate(c1):
        r.rid = 3000 + i
    got1 = router.serve_stream(c1, warmup=False)
    assert len(got1) == len(c1)
    for r in router.replicas:
        assert r.engine.metrics.compiles_post_warmup == 0
    router.close()


def test_fleet_restart_restores_fleet_lattice():
    router = FleetRouter(_fleet_factory, 3, auto_restart=False,
                         heartbeat_interval_s=float("inf"))
    reqs = make_stream(MIX, n_requests=48, seed=12)
    router.serve_stream(reqs)
    weights = {}
    for rep in router.replicas:
        for geom, w in rep.engine.shape_histogram.geometry_weights().items():
            weights[geom] = weights.get(geom, 0.0) + w
    new = optimize_lattice(weights, max_executables=4, batch=4)
    epoch = router.rewarm_lattice(new)["epoch"]
    rep = router.replicas[0]
    rep.health.on_failure(0.0, fatal=True)       # crash -> DEAD
    router.restart(rep.name)
    eng = router.replicas[0].engine
    assert eng.lattice_epoch() == epoch          # not a cold pow2 engine
    assert eng.lattice().corners == new.corners
    router.close()


# ---------------------------------------------------------------------------
# Property layer (hypothesis; skipped visibly when unavailable)
# ---------------------------------------------------------------------------


if given is not None:
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")

    shapes = st.tuples(st.integers(8, 2000), st.integers(1, 64),
                       st.integers(1, 32))

    @given(st.dictionaries(shapes, st.floats(0.1, 10.0),
                           min_size=1, max_size=16),
           st.integers(1, 16), st.sampled_from((1, 4, 8)))
    def test_optimizer_invariants_property(weights, budget, batch):
        """Coverage, budget, and monotone-vs-pow2 hold for ANY observed
        traffic, any executable budget, and any micro-batch costing."""
        weights = _well_posed(weights)
        lat = optimize_lattice(weights, max_executables=budget,
                               batch=batch)
        _check_invariants(weights, lat, budget)

    @given(st.dictionaries(shapes, st.floats(0.1, 10.0),
                           min_size=1, max_size=8))
    def test_adaptive_never_beats_real_work(weights):
        """padding_waste is >= 1 on every lattice: padded work can
        approach, never undercut, the real work."""
        weights = _well_posed(weights)
        lat = optimize_lattice(weights, max_executables=8)
        assert padding_waste(lat, weights) >= 1.0 - 1e-9
        assert padding_waste(Lattice(), weights) >= 1.0 - 1e-9
else:                                            # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_optimizer_invariants_property():
        ...
