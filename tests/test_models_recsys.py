"""RecSys models: forward/loss/serve/retrieval + training sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.batches import make_deepfm_batch, make_seqrec_batch
from repro.models.recsys import RECSYS_REGISTRY, RecsysConfig
from repro.optim import adam_init

SMALL = dict(
    deepfm=RecsysConfig(kind="deepfm", n_sparse=5, field_vocab=100,
                        embed_dim=8, mlp_dims=(16, 16)),
    sasrec=RecsysConfig(kind="sasrec", n_items=200, embed_dim=16, n_blocks=2,
                        n_heads=1, seq_len=10),
    bert4rec=RecsysConfig(kind="bert4rec", n_items=200, embed_dim=16,
                          n_blocks=2, n_heads=2, seq_len=12),
    mind=RecsysConfig(kind="mind", n_items=200, embed_dim=16, n_interests=3,
                      capsule_iters=2, seq_len=10),
)


def _batch(cfg, B=16, key=None):
    key = key or jax.random.key(0)
    if cfg.kind == "deepfm":
        return make_deepfm_batch(key, batch=B, n_sparse=cfg.n_sparse,
                                 field_vocab=cfg.field_vocab)
    return make_seqrec_batch(key, batch=B, seq_len=cfg.seq_len,
                             n_items=cfg.n_items, n_neg=7, kind=cfg.kind,
                             n_mask=4)


@pytest.mark.parametrize("kind", list(SMALL))
def test_loss_finite_and_trains(kind):
    cfg = SMALL[kind]
    model = RECSYS_REGISTRY[kind](cfg)
    params = model.init(jax.random.key(0))
    opt = adam_init(params)
    batch = _batch(cfg)

    @jax.jit
    def step(p, o, b):
        return model.train_step(p, o, b, lr=1e-2)

    losses = []
    for _ in range(15):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("kind", list(SMALL))
def test_serve_and_retrieval_shapes(kind):
    cfg = SMALL[kind]
    model = RECSYS_REGISTRY[kind](cfg)
    params = model.init(jax.random.key(0))
    B, n_cand = 4, 50
    cand = jnp.arange(n_cand)
    if kind == "deepfm":
        ids = _batch(cfg, B)["ids"]
        s = model.serve(params, ids)
        scores = model.retrieval_scores(params, ids[:, 1:], cand)
        X = model.user_covariates(params, ids)
        assert X.shape == (B, cfg.embed_dim)
    else:
        seq = _batch(cfg, B)["seq"]
        s = model.serve(params, seq, jnp.zeros((B,), jnp.int32))
        scores = model.retrieval_scores(params, seq, cand)
        X = model.user_covariates(params, seq)
        d_cov = (cfg.n_interests * cfg.embed_dim if kind == "mind"
                 else cfg.embed_dim)
        assert X.shape == (B, d_cov)
    assert s.shape == (B,)
    assert scores.shape == (B, n_cand)
    assert bool(jnp.all(jnp.isfinite(scores)))


def test_bert4rec_is_bidirectional_sasrec_causal():
    """BERT4Rec: early states see late items; SASRec: they must not."""
    for kind, causal in (("sasrec", True), ("bert4rec", False)):
        cfg = SMALL[kind]
        model = RECSYS_REGISTRY[kind](cfg)
        params = model.init(jax.random.key(0))
        seq1 = jnp.arange(cfg.seq_len)[None, :] % cfg.n_items
        seq2 = seq1.at[0, -1].set((seq1[0, -1] + 7) % cfg.n_items)
        h1 = model.encode(params, seq1)
        h2 = model.encode(params, seq2)
        first_same = bool(jnp.allclose(h1[0, 0], h2[0, 0], atol=1e-6))
        assert first_same == causal


def test_mind_interest_capsules():
    cfg = SMALL["mind"]
    model = RECSYS_REGISTRY["mind"](cfg)
    params = model.init(jax.random.key(0))
    seq = _batch(cfg, 4)["seq"]
    u = model.interests(params, seq)
    assert u.shape == (4, cfg.n_interests, cfg.embed_dim)
    norms = jnp.linalg.norm(u, axis=-1)
    assert bool(jnp.all(norms <= 1.0 + 1e-5))  # squash bounds capsules
