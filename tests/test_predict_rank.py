"""Single-sweep predict+rank+audit (kernels.ops.predict_rank_audited)
vs the two-stage oracle `predictor.predict(X)` -> `rank_given_lambda`,
for all four predictor families.

Parity contract (the dispatcher's docstring, asserted here):
  * linear / mean — the affine prologue folded into the rank kernel is
    BITWISE identical on the interpret path (same jnp.dot + max ops as
    LinearLambdaPredictor.predict, executed per batch tile in VMEM);
  * knn — the fused inverse-distance weighting agrees to tight
    tolerance (per-tile vs one-matmul distance accumulation differs in
    the last ulp); selection and audit outputs still agree exactly on
    these fixed-seed problems (score gaps are orders of magnitude above
    the λ̂ perturbation);
  * mlp — λ̂ stays XLA inside the same executable: bitwise.

Plus: bucket-padded micro-batches (phantom rows, padded K tier), the
m2 = MAX_KERNEL_M2 edge, the m2 > MAX_KERNEL_M2 XLA fallback, and the
fused KNN λ kernel against its oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.predictors import (
    KNNLambdaPredictor,
    LinearLambdaPredictor,
    MeanLambdaPredictor,
    MLPLambdaPredictor,
)
from repro.core.ranking import rank_given_lambda
from repro.kernels import ops, ref
from repro.kernels.fused_rank import MAX_KERNEL_M2

KEY = jax.random.key(11)

FIELDS = ("perm", "utility", "exposure", "compliant")

D_COV = 12


def _problem(n, m1, K, m2, d=D_COV, salt=0):
    ks = jax.random.split(jax.random.fold_in(KEY, n * m1 + K + salt), 7)
    u = jax.random.uniform(ks[0], (n, m1), minval=1.0, maxval=5.0)
    a = (jax.random.uniform(ks[1], (n, K, m1)) < 0.15).astype(jnp.float32)
    b = jnp.abs(jax.random.normal(ks[2], (n, K)))
    gamma = jnp.abs(jax.random.normal(ks[3], (n, m2)))
    X = jax.random.normal(ks[4], (n, d))
    X_tr = jax.random.uniform(ks[5], (48, d))
    lam_tr = jnp.abs(jax.random.normal(ks[6], (48, K)))
    return u, a, b, gamma, X, X_tr, lam_tr


def _families(X_tr, lam_tr):
    return {
        "linear": LinearLambdaPredictor.fit(X_tr, lam_tr),
        "mean": MeanLambdaPredictor.fit(X_tr, lam_tr),
        "knn": KNNLambdaPredictor.fit(X_tr, lam_tr, k=5),
        "mlp": MLPLambdaPredictor.fit(X_tr, lam_tr, num_steps=25),
    }


def _assert_fields_equal(got, want, pad_k=0, msg=""):
    for field in FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(got, field)), np.asarray(getattr(want, field)),
            err_msg=f"predict+rank parity broke on {field} {msg}")


@pytest.mark.parametrize("n,m1,K,m2", [
    (8, 512, 5, 10),
    (3, 700, 2, 8),                 # off-tile n and m1 exercise padding
    (8, 1024, 3, MAX_KERNEL_M2),    # m2 edge: the largest kernel path
])
def test_predict_rank_matches_two_stage_oracle(n, m1, K, m2):
    u, a, b, gamma, X, X_tr, lam_tr = _problem(n, m1, K, m2)
    for name, pred in _families(X_tr, lam_tr).items():
        got = ops.predict_rank_audited(X, pred, u, a, b, gamma, m2=m2,
                                       interpret=True)
        want = rank_given_lambda(u, a, b, pred.predict(X), gamma, m2=m2)
        _assert_fields_equal(got, want, msg=f"[{name}]")
        if name == "knn":
            # per-tile distance accumulation: λ̂ to the last ulp
            np.testing.assert_allclose(
                np.asarray(got.lam), np.asarray(want.lam),
                rtol=1e-5, atol=1e-6, err_msg="fused KNN weighting drifted")
        else:
            # affine prologue / in-executable MLP: λ̂ bitwise
            np.testing.assert_array_equal(
                np.asarray(got.lam), np.asarray(want.lam),
                err_msg=f"λ̂ parity broke for {name}")


def test_mean_family_preserves_unclamped_negative_lambda():
    """The mean predictor broadcasts mean_lam verbatim (no clamp); the
    prologue's relu must stay OFF for it — a synthetic negative mean
    would otherwise be silently zeroed and the parity would hide it."""
    n, m1, K, m2 = 8, 512, 3, 8
    u, a, b, gamma, X, X_tr, _ = _problem(n, m1, K, m2, salt=1)
    lam_tr = jax.random.normal(jax.random.fold_in(KEY, 5), (48, K)) - 0.5
    pred = MeanLambdaPredictor.fit(X_tr, lam_tr)
    assert bool(jnp.any(pred.mean_lam < 0))     # the case under test
    got = ops.predict_rank_audited(X, pred, u, a, b, gamma, m2=m2,
                                   interpret=True)
    want = rank_given_lambda(u, a, b, pred.predict(X), gamma, m2=m2)
    _assert_fields_equal(got, want)
    np.testing.assert_array_equal(np.asarray(got.lam), np.asarray(want.lam))


def test_predict_rank_bucket_padded_batch():
    """An engine-style padded micro-batch on the covariate path:
    phantom rows (X = 0), NEG_FILL candidate padding, a K tier wider
    than the predictor's output — parity with the two-stage oracle on
    the whole padded problem, zero audit on phantom rows."""
    from repro.serving import Scenario, assemble_batch, bucket_for, make_request

    d, K_pred = 10, 4
    rng = np.random.default_rng(2)
    sc = Scenario("cov", m1=300, m2=20, K=K_pred, tag="arch", d_cov=d)
    reqs = [make_request(rng, sc, rid) for rid in range(5)]
    bucket = bucket_for(m1=max(r.u.shape[0] for r in reqs), m2=20,
                       K=8, tag="arch", batch=8)     # padded K tier + rows
    staged = assemble_batch(reqs, bucket, d_cov=d)
    u = jnp.asarray(staged["u"])
    a = jnp.asarray(staged["a"])
    b = jnp.asarray(staged["b"])
    gamma = jnp.asarray(staged["gamma"])
    X = jnp.asarray(staged["X"])
    X_tr = jnp.asarray(rng.uniform(0, 1, (32, d)), jnp.float32)
    lam_tr = jnp.asarray(np.abs(rng.normal(size=(32, K_pred))), jnp.float32)

    for pred in (LinearLambdaPredictor.fit(X_tr, lam_tr),
                 KNNLambdaPredictor.fit(X_tr, lam_tr, k=5)):
        got = ops.predict_rank_audited(X, pred, u, a, b, gamma,
                                       m2=bucket.m2, interpret=True)
        lam = jnp.pad(pred.predict(X), ((0, 0), (0, bucket.K - K_pred)))
        want = rank_given_lambda(u, a, b, lam, gamma, m2=bucket.m2)
        n_real = len(reqs)
        for field in FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(got, field))[:n_real],
                np.asarray(getattr(want, field))[:n_real],
                err_msg=f"padded covariate batch broke on {field}")
        # phantom rows: zero gamma -> zero utility, trivially compliant
        np.testing.assert_array_equal(np.asarray(got.utility[n_real:]), 0.0)
        assert bool(np.all(np.asarray(got.compliant[n_real:])))


def test_affine_prologue_lane_padded_ragged_d_exact():
    """The TPU lane-alignment path: padding a ragged covariate dim d to
    the 128-lane boundary with zero X/W columns must leave every output
    bitwise unchanged (trailing zeros append exactly-0.0 terms at the
    end of the prologue dot's reduction). The pad is gated OFF on the
    interpret path by default — forcing it on here proves the gate is
    caution about reduction-order, not a correctness requirement."""
    n, m1, K, m2 = 8, 512, 3, 10
    d = 12                                     # ragged: pads to 128
    u, a, b, gamma, X, X_tr, lam_tr = _problem(n, m1, K, m2, d=d, salt=7)
    for pred in (LinearLambdaPredictor.fit(X_tr, lam_tr),
                 MeanLambdaPredictor.fit(X_tr, lam_tr)):
        plain = ops.predict_rank_audited(X, pred, u, a, b, gamma, m2=m2,
                                         interpret=True)
        padded = ops.predict_rank_audited(X, pred, u, a, b, gamma, m2=m2,
                                          interpret=True, pad_lanes=True)
        for field in FIELDS + ("lam",):
            np.testing.assert_array_equal(
                np.asarray(getattr(padded, field)),
                np.asarray(getattr(plain, field)),
                err_msg=f"lane padding changed {field} for "
                        f"{type(pred).__name__}")
        want = rank_given_lambda(u, a, b, pred.predict(X), gamma, m2=m2)
        _assert_fields_equal(padded, want)


def test_predict_rank_xla_fallback_large_m2():
    """m2 > MAX_KERNEL_M2 routes to the two-stage XLA oracle: the
    dispatcher must reproduce ref.rank_audited_ref on the predictor's
    own λ̂, bitwise, for every family. (ref ↔ rank_given_lambda parity
    under matched numerics is tests/test_rank_audited.py's job; eager
    vs jit'd score epilogues may legitimately swap last-ulp-tied
    neighbours, so the oracle here is the same eager program the
    fallback runs.)"""
    n, m1, K, m2 = 4, 700, 3, MAX_KERNEL_M2 + 72
    u, a, b, gamma, X, X_tr, lam_tr = _problem(n, m1, K, m2, salt=2)
    for name, pred in _families(X_tr, lam_tr).items():
        got = ops.predict_rank_audited(X, pred, u, a, b, gamma, m2=m2)
        _, idx, utility, exposure, compliant = ref.rank_audited_ref(
            u, a, b, pred.predict(X).astype(jnp.float32), gamma, m2)
        np.testing.assert_array_equal(
            np.asarray(got.perm), np.asarray(idx),
            err_msg=f"fallback perm broke [{name}]")
        np.testing.assert_array_equal(
            np.asarray(got.utility), np.asarray(utility),
            err_msg=f"fallback utility broke [{name}]")
        np.testing.assert_array_equal(
            np.asarray(got.exposure), np.asarray(exposure),
            err_msg=f"fallback exposure broke [{name}]")
        np.testing.assert_array_equal(
            np.asarray(got.compliant), np.asarray(compliant),
            err_msg=f"fallback compliance broke [{name}]")


def test_predict_rank_shared_broadcast_forms():
    """(K, m1) a, (K,) b, (m2,) gamma broadcast exactly like the
    two-stage path."""
    n, m1, K, m2 = 6, 512, 4, 16
    u, a, b, gamma, X, X_tr, lam_tr = _problem(n, m1, K, m2, salt=3)
    pred = LinearLambdaPredictor.fit(X_tr, lam_tr)
    got = ops.predict_rank_audited(X, pred, u, a[0], b[0], gamma[0],
                                   m2=m2, interpret=True)
    want = rank_given_lambda(u, a[0], b[0], pred.predict(X), gamma[0], m2=m2)
    _assert_fields_equal(got, want)


def test_predict_rank_rejects_too_wide_predictor():
    """A predictor emitting more shadow prices than the problem has
    constraint rows is a configuration error, not silence."""
    n, m1, K, m2 = 8, 512, 2, 8
    u, a, b, gamma, X, X_tr, _ = _problem(n, m1, K, m2, salt=4)
    lam_tr = jnp.abs(jax.random.normal(jax.random.fold_in(KEY, 9), (48, 5)))
    pred = LinearLambdaPredictor.fit(X_tr, lam_tr)      # 5 > K = 2
    with pytest.raises(ValueError, match="shadow prices"):
        ops.predict_rank_audited(X, pred, u, a, b, gamma, m2=m2,
                                 interpret=True)
    # the XLA fallback branch raises the same purposeful error
    gamma_big = jnp.abs(jax.random.normal(
        jax.random.fold_in(KEY, 10), (n, MAX_KERNEL_M2 + 8)))
    with pytest.raises(ValueError, match="shadow prices"):
        ops.predict_rank_audited(X, pred, u, a, b, gamma_big,
                                 m2=MAX_KERNEL_M2 + 8)


def test_predict_rank_rejects_row_count_mismatch():
    """X with fewer rows than u must be a loud error — the kernel path
    pads X for tiling and would otherwise intercept-serve the
    uncovered rows."""
    n, m1, K, m2 = 8, 512, 3, 8
    u, a, b, gamma, X, X_tr, lam_tr = _problem(n, m1, K, m2, salt=6)
    pred = LinearLambdaPredictor.fit(X_tr, lam_tr)
    with pytest.raises(ValueError, match="covariate rows"):
        ops.predict_rank_audited(X[:4], pred, u, a, b, gamma, m2=m2,
                                 interpret=True)


def test_knn_lambda_rejects_too_small_db():
    """n_train < k errors like every other KNN path instead of letting
    the far-away padding rows into the top-k."""
    with pytest.raises(ValueError, match="n_train"):
        ops.knn_lambda(jnp.zeros((4, 3)), jnp.zeros((4, 3)),
                       jnp.zeros((4, 2)), k=10, interpret=True)


# ---------------------------------------------------------------------------
# The fused KNN λ kernel on its own
# ---------------------------------------------------------------------------


def test_knn_lambda_kernel_matches_ref_and_predictor():
    """knn_lambda (payload-carried weighting at the flush step) agrees
    with its argsort oracle and with core.predictors.knn_predict,
    including the exact-match override (query == db row)."""
    from repro.core.predictors import knn_predict

    ks = jax.random.split(jax.random.fold_in(KEY, 21), 3)
    X_db = jax.random.normal(ks[0], (600, 16))
    lam_db = jnp.abs(jax.random.normal(ks[1], (600, 5)))
    Xq = jnp.concatenate([jax.random.normal(ks[2], (9, 16)), X_db[:3]])
    got = ops.knn_lambda(Xq, X_db, lam_db, k=10, interpret=True)
    want_ref = ref.knn_lambda_ref(Xq, X_db, lam_db, 10)
    want_pred = knn_predict(X_db, lam_db, Xq, k=10)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want_ref),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want_pred),
                               rtol=1e-5, atol=1e-6)
    # exact-match rows return the training value (sklearn semantics)
    np.testing.assert_allclose(np.asarray(got[-3:]), np.asarray(lam_db[:3]),
                               rtol=1e-4, atol=1e-5)


def test_knn_lambda_tile_q_selection_consistent():
    """The wide (tile_q=32) and narrow (tile_q=8) query tilings give
    the same λ̂ — tile geometry is a traffic knob, not semantics."""
    ks = jax.random.split(jax.random.fold_in(KEY, 22), 2)
    X_db = jax.random.normal(ks[0], (256, 8))
    lam_db = jnp.abs(jax.random.normal(ks[1], (256, 3)))
    Xq = jax.random.normal(jax.random.fold_in(KEY, 23), (40, 8))
    wide = ops.knn_lambda(Xq, X_db, lam_db, k=5, tile_q=32, interpret=True)
    narrow = ops.knn_lambda(Xq, X_db, lam_db, k=5, tile_q=8, interpret=True)
    np.testing.assert_allclose(np.asarray(wide), np.asarray(narrow),
                               rtol=1e-6, atol=1e-7)
