"""Shadow-price predictors: exactness, consistency, registry interface."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.predictors as predictors_mod
from repro.core.predictors import (
    KNNLambdaPredictor,
    LinearLambdaPredictor,
    MeanLambdaPredictor,
    MLPLambdaPredictor,
    knn_predict,
    knn_predict_chunked,
)
from repro.optim import adam_init, adam_update


def _data(seed=0, n=200, d=6, K=3):
    rng = np.random.default_rng(seed)
    # X >= 0 and W >= 0 keep lam = XW^T + noise positive without clipping
    # (clipping would make the map non-linear and break the ridge test)
    X = rng.uniform(0, 1, size=(n, d)).astype(np.float32)
    W = rng.uniform(0, 1, size=(K, d)).astype(np.float32)
    lam = np.maximum(X @ W.T + 0.05 * rng.normal(size=(n, K)), 0).astype(np.float32)
    return jnp.asarray(X), jnp.asarray(lam)


def test_mean_predictor():
    X, lam = _data()
    p = MeanLambdaPredictor.fit(X, lam)
    out = p.predict(X[:5])
    np.testing.assert_allclose(out, jnp.broadcast_to(jnp.mean(lam, 0), (5, 3)),
                               rtol=1e-5)


def test_knn_exact_match_returns_training_value():
    """sklearn 'distance'-weights semantics: query == db point -> that
    point's target exactly."""
    X, lam = _data()
    p = KNNLambdaPredictor.fit(X, lam, k=10)
    out = p.predict(X[:20])
    np.testing.assert_allclose(out, lam[:20], rtol=1e-4, atol=1e-4)


def test_knn_interpolates_between_neighbors():
    X = jnp.asarray([[0.0], [1.0]])
    lam = jnp.asarray([[0.0], [1.0]])
    out = knn_predict(X, lam, jnp.asarray([[0.25]]), k=2)
    # inverse-distance weights: w = (4, 4/3) -> normalized (0.75, 0.25)
    np.testing.assert_allclose(out, [[0.25]], rtol=1e-4)


def test_knn_consistency_improves_with_data():
    """KNN regression is consistent: more data -> lower error on E[lam|X].
    Train and test must come from ONE draw (same ground-truth map)."""
    X_all, lam_all = _data(seed=1, n=1000)
    Xt, lamt = X_all[-100:], lam_all[-100:]
    errs = []
    for n in (50, 900):
        p = KNNLambdaPredictor.fit(X_all[:n], lam_all[:n], k=10)
        errs.append(float(jnp.mean((p.predict(Xt) - lamt) ** 2)))
    assert errs[1] < errs[0]


def test_linear_recovers_linear_map():
    X, lam = _data(seed=2, n=500)
    p = LinearLambdaPredictor.fit(X, lam, l2=1e-6)
    pred = p.predict(X)
    resid = float(jnp.mean((pred - lam) ** 2))
    base = float(jnp.mean((lam - jnp.mean(lam, 0)) ** 2))
    assert resid < 0.1 * base


def test_mlp_trains():
    X, lam = _data(seed=3, n=300)
    p = MLPLambdaPredictor.fit(X, lam, num_steps=200, d_hidden=32)
    pred = p.predict(X)
    base = float(jnp.mean((lam - jnp.mean(lam, 0)) ** 2))
    assert float(jnp.mean((pred - lam) ** 2)) < 0.5 * base
    assert bool(jnp.all(pred >= 0))  # softplus head: dual feasible


def test_mlp_scan_fit_matches_python_loop():
    """The lax.scan training loop (one jit dispatch) must reproduce the
    old per-step-jit Python loop exactly — same init, same Adam, same
    order of operations, so the fitted params are unchanged bitwise."""
    X, lam = _data(seed=4, n=150)
    steps, lr = 40, 1e-2
    p_scan, losses = MLPLambdaPredictor.fit(
        X, lam, num_steps=steps, d_hidden=32, return_trace=True)

    params = MLPLambdaPredictor.init_params(
        jax.random.key(0), X.shape[1], 32, lam.shape[1])
    opt = adam_init(params)

    def loss_fn(p):
        return jnp.mean((MLPLambdaPredictor.apply(p, X) - lam) ** 2)

    @jax.jit
    def step(p, o):
        loss, g = jax.value_and_grad(loss_fn)(p)
        p, o = adam_update(g, o, p, lr=lr)
        return p, o, loss

    loop_losses = []
    for _ in range(steps):
        params, opt, l = step(params, opt)
        loop_losses.append(float(l))

    for k in params:
        np.testing.assert_array_equal(
            np.asarray(p_scan.params[k]), np.asarray(params[k]),
            err_msg=f"scan-fit drifted from the loop fit on {k}")
    assert losses.shape == (steps,)
    np.testing.assert_allclose(np.asarray(losses), loop_losses, rtol=1e-6)
    # the trace is the training curve: it must actually descend
    assert float(losses[-1]) < float(losses[0])


def test_mlp_fit_default_returns_predictor_only():
    X, lam = _data(seed=5, n=60)
    p = MLPLambdaPredictor.fit(X, lam, num_steps=5, d_hidden=16)
    assert isinstance(p, MLPLambdaPredictor)


def test_knn_chunked_matches_full_matrix():
    """The chunked db sweep is the same estimator as the one-matmul
    path: same neighbours (ties to lower global index), same weights,
    exact-match override included — on chunk sizes that do and do not
    divide n_train."""
    rng = np.random.default_rng(7)
    X_db = jnp.asarray(rng.normal(size=(500, 9)), jnp.float32)
    lam_db = jnp.asarray(np.abs(rng.normal(size=(500, 4))), jnp.float32)
    Xq = jnp.concatenate([
        jnp.asarray(rng.normal(size=(11, 9)), jnp.float32),
        X_db[100:103],                       # exact-match rows
    ])
    full = knn_predict(X_db, lam_db, Xq, k=10)
    for chunk in (128, 500, 333):            # divides / whole / ragged
        got = knn_predict_chunked(X_db, lam_db, Xq, k=10, chunk=chunk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                                   rtol=1e-6, atol=1e-7,
                                   err_msg=f"chunk={chunk}")
    # 1-D query squeeze contract matches too
    np.testing.assert_allclose(
        np.asarray(knn_predict_chunked(X_db, lam_db, Xq[0], k=5, chunk=200)),
        np.asarray(knn_predict(X_db, lam_db, Xq[0], k=5)),
        rtol=1e-6, atol=1e-7)


def test_knn_chunked_rejects_too_small_db():
    X_db = jnp.zeros((4, 3))
    lam_db = jnp.zeros((4, 2))
    with pytest.raises(ValueError, match="n_train"):
        knn_predict_chunked(X_db, lam_db, jnp.zeros((2, 3)), k=10)


def test_knn_predictor_routes_chunked_above_threshold(monkeypatch):
    """KNNLambdaPredictor.predict flips to the chunked path above the
    size threshold and the answer does not change."""
    rng = np.random.default_rng(8)
    X_db = rng.normal(size=(300, 6)).astype(np.float32)
    lam_db = np.abs(rng.normal(size=(300, 3))).astype(np.float32)
    Xq = jnp.asarray(rng.normal(size=(9, 6)), jnp.float32)
    p = KNNLambdaPredictor.fit(X_db, lam_db, k=10)
    full = p.predict(Xq)

    routed = {"chunked": 0}
    real = predictors_mod.knn_predict_chunked

    def counting(*args, **kwargs):
        routed["chunked"] += 1
        return real(*args, **kwargs)

    monkeypatch.setattr(predictors_mod, "KNN_CHUNK_THRESHOLD", 100)
    monkeypatch.setattr(predictors_mod, "knn_predict_chunked", counting)
    got = p.predict(Xq)
    assert routed["chunked"] == 1
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=1e-6, atol=1e-7)


def test_predictors_are_pytrees():
    X, lam = _data()
    p = KNNLambdaPredictor.fit(X, lam, k=5)
    leaves = jax.tree.leaves(p)
    assert len(leaves) >= 2  # X_db, lam_db ride along for donation/sharding
