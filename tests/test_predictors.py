"""Shadow-price predictors: exactness, consistency, registry interface."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.predictors import (
    KNNLambdaPredictor,
    LinearLambdaPredictor,
    MeanLambdaPredictor,
    MLPLambdaPredictor,
    knn_predict,
)


def _data(seed=0, n=200, d=6, K=3):
    rng = np.random.default_rng(seed)
    # X >= 0 and W >= 0 keep lam = XW^T + noise positive without clipping
    # (clipping would make the map non-linear and break the ridge test)
    X = rng.uniform(0, 1, size=(n, d)).astype(np.float32)
    W = rng.uniform(0, 1, size=(K, d)).astype(np.float32)
    lam = np.maximum(X @ W.T + 0.05 * rng.normal(size=(n, K)), 0).astype(np.float32)
    return jnp.asarray(X), jnp.asarray(lam)


def test_mean_predictor():
    X, lam = _data()
    p = MeanLambdaPredictor.fit(X, lam)
    out = p.predict(X[:5])
    np.testing.assert_allclose(out, jnp.broadcast_to(jnp.mean(lam, 0), (5, 3)),
                               rtol=1e-5)


def test_knn_exact_match_returns_training_value():
    """sklearn 'distance'-weights semantics: query == db point -> that
    point's target exactly."""
    X, lam = _data()
    p = KNNLambdaPredictor.fit(X, lam, k=10)
    out = p.predict(X[:20])
    np.testing.assert_allclose(out, lam[:20], rtol=1e-4, atol=1e-4)


def test_knn_interpolates_between_neighbors():
    X = jnp.asarray([[0.0], [1.0]])
    lam = jnp.asarray([[0.0], [1.0]])
    out = knn_predict(X, lam, jnp.asarray([[0.25]]), k=2)
    # inverse-distance weights: w = (4, 4/3) -> normalized (0.75, 0.25)
    np.testing.assert_allclose(out, [[0.25]], rtol=1e-4)


def test_knn_consistency_improves_with_data():
    """KNN regression is consistent: more data -> lower error on E[lam|X].
    Train and test must come from ONE draw (same ground-truth map)."""
    X_all, lam_all = _data(seed=1, n=1000)
    Xt, lamt = X_all[-100:], lam_all[-100:]
    errs = []
    for n in (50, 900):
        p = KNNLambdaPredictor.fit(X_all[:n], lam_all[:n], k=10)
        errs.append(float(jnp.mean((p.predict(Xt) - lamt) ** 2)))
    assert errs[1] < errs[0]


def test_linear_recovers_linear_map():
    X, lam = _data(seed=2, n=500)
    p = LinearLambdaPredictor.fit(X, lam, l2=1e-6)
    pred = p.predict(X)
    resid = float(jnp.mean((pred - lam) ** 2))
    base = float(jnp.mean((lam - jnp.mean(lam, 0)) ** 2))
    assert resid < 0.1 * base


def test_mlp_trains():
    X, lam = _data(seed=3, n=300)
    p = MLPLambdaPredictor.fit(X, lam, num_steps=200, d_hidden=32)
    pred = p.predict(X)
    base = float(jnp.mean((lam - jnp.mean(lam, 0)) ** 2))
    assert float(jnp.mean((pred - lam) ** 2)) < 0.5 * base
    assert bool(jnp.all(pred >= 0))  # softplus head: dual feasible


def test_predictors_are_pytrees():
    X, lam = _data()
    p = KNNLambdaPredictor.fit(X, lam, k=5)
    leaves = jax.tree.leaves(p)
    assert len(leaves) >= 2  # X_db, lam_db ride along for donation/sharding
