"""MeshGraphNet: shapes, permutation equivariance, sampler, training."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.batches import make_csr_graph, make_molecule_batch, make_random_graph
from repro.models.gnn import (
    GNNConfig,
    MeshGraphNet,
    block_graph_from_sample,
    neighbor_sample,
    sampled_sizes,
)
from repro.optim import adam_init

CFG = GNNConfig(n_layers=3, d_hidden=24, d_node_in=8, d_edge_in=4, d_out=3,
                remat=False)


@pytest.fixture(scope="module")
def setup():
    model = MeshGraphNet(CFG)
    params = model.init(jax.random.key(0))
    g = make_random_graph(jax.random.key(1), n_nodes=30, n_edges=80,
                          d_node=8, d_edge=4, d_out=3)
    return model, params, g


def test_forward_shapes(setup):
    model, params, g = setup
    out = model.forward(params, g)
    assert out.shape == (30, 3)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_permutation_equivariance(setup):
    """Relabeling nodes permutes outputs identically — the core GNN
    invariant."""
    model, params, g = setup
    N = g["nodes"].shape[0]
    perm = np.asarray(jax.random.permutation(jax.random.key(7), N))
    inv = np.empty_like(perm)
    inv[perm] = np.arange(N)
    g2 = {
        "nodes": g["nodes"][perm],
        "edges": g["edges"],
        "senders": jnp.asarray(inv)[g["senders"]],
        "receivers": jnp.asarray(inv)[g["receivers"]],
        "targets": g["targets"][perm],
    }
    out1 = model.forward(params, g)
    out2 = model.forward(params, g2)
    np.testing.assert_allclose(out1[perm], out2, rtol=2e-4, atol=2e-4)


def test_isolated_nodes_get_zero_messages(setup):
    model, params, _ = setup
    # two nodes, one edge 0 -> 1: node 1 aggregates, node 0 receives nothing
    g = {
        "nodes": jnp.ones((2, 8)),
        "edges": jnp.ones((1, 4)),
        "senders": jnp.asarray([0]),
        "receivers": jnp.asarray([1]),
    }
    out = model.forward(params, g)
    assert out.shape == (2, 3)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_train_loss_decreases(setup):
    model, params, g = setup
    opt = adam_init(params)

    @jax.jit
    def step(p, o):
        return model.train_step(p, o, g, lr=3e-3)

    losses = []
    for _ in range(25):
        params, opt, m = step(params, opt)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[::8]


def test_batched_molecule_mode(setup):
    model, params, _ = setup
    gb = make_molecule_batch(jax.random.key(2), batch=3, n_nodes=6,
                             n_edges=10, d_node=8, d_edge=4, d_out=3)
    loss, _ = model.loss(params, gb)
    assert np.isfinite(float(loss))


def test_neighbor_sampler_static_shapes():
    indptr, indices = make_csr_graph(jax.random.key(3), n_nodes=500,
                                     avg_degree=6)
    seeds = jnp.arange(16)
    s = neighbor_sample(jax.random.key(4), indptr, indices, seeds,
                        fanouts=(5, 3))
    N, E = sampled_sizes(16, (5, 3))
    assert s["node_ids"].shape == (N,)
    assert s["senders"].shape == (E,)
    assert s["receivers"].shape == (E,)
    # receivers always point to an earlier (coarser) layer
    assert bool(jnp.all(s["receivers"] < s["senders"]))
    # all sampled ids are valid nodes
    assert bool(jnp.all((s["node_ids"] >= 0) & (s["node_ids"] < 500)))


def test_block_graph_runs_through_network():
    indptr, indices = make_csr_graph(jax.random.key(5), n_nodes=300,
                                     avg_degree=5)
    seeds = jnp.arange(8)
    s = neighbor_sample(jax.random.key(6), indptr, indices, seeds,
                        fanouts=(4, 2))
    feats = jax.random.normal(jax.random.key(7), (s["node_ids"].shape[0], 8))
    blk = block_graph_from_sample(s, feats, 4)
    model = MeshGraphNet(CFG)
    params = model.init(jax.random.key(0))
    out = model.forward(params, blk)
    assert out.shape == (s["node_ids"].shape[0], 3)


def test_node_scores_api_for_ranking_head(setup):
    """The paper-head API-compatibility check (DESIGN.md §5): GNN node
    scores can feed the constrained-ranking head."""
    from repro.core.constraints import dcg_discount
    from repro.core.dual_solver import serve_rank
    model, params, g = setup
    u = model.node_scores(params, g)                      # (N,)
    a = (jax.random.uniform(jax.random.key(8), (2, 30)) < 0.5).astype(jnp.float32)
    lam = jnp.asarray([0.1, 0.2])
    perm, util = serve_rank(u, a, lam, dcg_discount(5), m2=5)
    assert perm.shape == (5,)
