"""Multi-device semantics, run in a SUBPROCESS with 8 host devices so the
main test process keeps the single real CPU device.

Covers: distributed top-k merge == global top-k, sharded KNN == dense
KNN, compressed cross-pod psum accuracy, and one dry-run cell build on a
smoke mesh (sharding-rule plumbing under real SPMD execution).
"""

import os
import subprocess
import sys
import textwrap

import pytest

_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((2, 4), ("data", "model"))

    # ---- distributed top-k == global top-k --------------------------------
    from repro.distributed.topk import sharded_knn_topk, sharded_score_topk
    key = jax.random.key(0)
    xq = jax.random.normal(key, (8, 32))
    xdb = jax.random.normal(jax.random.fold_in(key, 1), (512, 32))
    xdb_sh = jax.device_put(xdb, NamedSharding(mesh, P("model", None)))
    d2, idx = sharded_knn_topk(mesh, xq, xdb_sh, k=10)
    # dense reference
    ref_d2 = (jnp.sum(xq**2, 1, keepdims=True) - 2 * xq @ xdb.T
              + jnp.sum(xdb**2, 1)[None])
    ref_d2 = jnp.maximum(ref_d2, 0)
    ref_top = jnp.sort(ref_d2, axis=1)[:, :10]
    np.testing.assert_allclose(np.sort(np.asarray(d2), 1), ref_top,
                               rtol=1e-4, atol=1e-4)
    gathered = jnp.take_along_axis(ref_d2, idx, axis=1)
    np.testing.assert_allclose(np.sort(np.asarray(gathered), 1), ref_top,
                               rtol=1e-4, atol=1e-4)
    print("sharded_knn_topk OK")

    scores = jax.random.normal(jax.random.fold_in(key, 2), (8, 256))
    scores_sh = jax.device_put(scores, NamedSharding(mesh, P(None, "model")))
    v, i = sharded_score_topk(mesh, scores_sh, 5)
    ref_v, ref_i = jax.lax.top_k(scores, 5)
    np.testing.assert_allclose(v, ref_v, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ref_i))
    print("sharded_score_topk OK")

    # ---- compressed cross-axis psum --------------------------------------
    from repro.optim.compression import compressed_psum
    x = jax.random.normal(jax.random.fold_in(key, 3), (8, 128))
    x_sh = jax.device_put(x, NamedSharding(mesh, P("data", None)))
    from repro.distributed.compat import shard_map
    out = shard_map(
        lambda xs: compressed_psum(xs, "data"),
        mesh=mesh, in_specs=P("data", None), out_specs=P("data", None),
        check_vma=False)(x_sh)
    # exact psum reference: sum over the data axis groups
    ref = jnp.tile(x[:4] + x[4:], (2, 1))
    rel = float(jnp.max(jnp.abs(out - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert rel < 0.02, rel   # int8 quantization error bound
    print("compressed_psum OK, rel err", rel)

    # ---- one dry-run cell builds, compiles AND RUNS on the smoke mesh ----
    from repro.launch.dryrun import run_cell
    from repro.launch.mesh import make_smoke_mesh
    smesh = make_smoke_mesh(multi_pod=True)
    rec = run_cell("llama3.2-1b", "train_4k", smesh, "t", smoke=True)
    assert rec["status"] == "ok", rec
    print("dryrun cell OK")

    # paper serve path executes under SPMD with real arrays
    from repro.configs.paper import PAPER_SMOKE_CELLS, build_paper, PaperConfig
    from repro.distributed.sharding import use_mesh_rules
    cell = [c for c in PAPER_SMOKE_CELLS if c.name == "serve_online"][0]
    low = build_paper(PaperConfig(), cell, smesh)
    args = [jax.tree.map(lambda s: jnp.full(s.shape, 0.25, s.dtype), a)
            for a in low.args]
    with use_mesh_rules(smesh, low.rules):
        out = jax.jit(low.fn)(*args)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(out)
               if jnp.issubdtype(x.dtype, jnp.floating))
    print("paper serve SPMD OK")

    # ---- distributed serving == dense serving (§Perf variant A) -----------
    from repro.core.predictors import knn_predict
    from repro.core.ranking import rank_given_lambda
    from repro.core.serving_dist import knn_predict_distributed, rank_distributed
    from repro.core.constraints import dcg_discount
    kk = jax.random.split(jax.random.key(9), 6)
    B, m1, K, n_db, d = 16, 64, 3, 128, 10
    X = jax.random.normal(kk[0], (B, d))
    X_db = jax.random.normal(kk[1], (n_db, d))
    lam_db = jnp.abs(jax.random.normal(kk[2], (n_db, K)))
    u = jax.random.uniform(kk[3], (B, m1))
    a = (jax.random.uniform(kk[4], (K, m1)) < 0.3).astype(jnp.float32)
    b = 0.1 * jnp.ones((K,))
    gamma = dcg_discount(8)
    lam_dense = knn_predict(X_db, lam_db, X, k=5)
    lam_dist = knn_predict_distributed(mesh, X_db, lam_db, X, k=5)
    np.testing.assert_allclose(lam_dist, lam_dense, rtol=1e-4, atol=1e-5)

    # ---- sharded QUANTIZED sweep == dense quantized predict ---------------
    # pack at a slab that divides the per-shard row count (128 rows over
    # 4 model shards -> 32/shard, slab=16): the global pack row-shards
    # cleanly, each shard holds whole slabs with their scales, and the
    # exact-on-x-tilde per-shard values make the k*shards merge bitwise
    # the dense selection.
    from repro.core.predictors import knn_predict_quant, pack_knn_db
    from repro.core.serving_dist import knn_predict_quant_distributed
    Xp_q, sc_q, y2q_q = pack_knn_db(X_db, mode="int8", slab=16)
    assert Xp_q.shape[0] == n_db  # no pad rows under this geometry
    lam_qd = knn_predict_quant(Xp_q, sc_q, y2q_q, lam_db, X, k=5,
                               mode="int8")
    lam_qdist = knn_predict_quant_distributed(
        mesh, Xp_q, sc_q, y2q_q, lam_db, X, k=5, mode="int8")
    np.testing.assert_allclose(np.asarray(lam_qdist), np.asarray(lam_qd),
                               rtol=5e-7, atol=1e-7)
    print("sharded quantized sweep OK")

    # the slab-streaming shard body vs the retired dense-matrix body:
    # the old body materialized the per-shard (B_l, n_l) distance
    # matrix; the new one streams knn_topk_scan slabs. Selection is
    # BITWISE identical (indices + gathered |x_n|^2 payload); the
    # distance VALUES may differ in the last ulp (the slab dot compiles
    # inside a scan body and XLA rounds the fused x2 - 2qx + y2 chain
    # differently there), so λ̂ is compared at 1-ulp tolerance.
    from repro.distributed.topk import distributed_top_k
    def old_dense_body(xq, xdb_local, lam_all):
        x2 = jnp.sum(xq * xq, axis=-1, keepdims=True)
        y2l = jnp.sum(xdb_local * xdb_local, axis=-1)
        d2 = jnp.maximum(x2 - 2.0 * (xq @ xdb_local.T) + y2l[None, :], 0.0)
        y2_b = jnp.broadcast_to(y2l[None, :], d2.shape)
        neg_d2, idx_g, y2_sel = distributed_top_k(-d2, 5, "model",
                                                  payload=y2_b)
        d2k = -neg_d2
        lam_nb = lam_all[idx_g]
        scale2 = x2 + y2_sel + 1e-12
        exact = d2k <= 1e-6 * scale2
        any_exact = jnp.any(exact, axis=-1, keepdims=True)
        w_inv = 1.0 / jnp.maximum(jnp.sqrt(d2k), 1e-12)
        w = jnp.where(any_exact, exact.astype(d2.dtype), w_inv)
        w = w / jnp.sum(w, axis=-1, keepdims=True)
        return idx_g, y2_sel, jnp.einsum("bk,bkc->bc", w, lam_nb)
    from repro.core.predictors import knn_topk_scan
    from repro.distributed.topk import gather_merge_top_k
    def new_selection_body(xq, xdb_local, lam_all):
        n_l = xdb_local.shape[0]
        neg_v, idx_l = knn_topk_scan(xdb_local, xq, k=5, chunk=n_l)
        y2l = jnp.sum(xdb_local * xdb_local, axis=-1)
        gidx = idx_l + jax.lax.axis_index("model") * n_l
        _, idx_g, y2_sel = gather_merge_top_k(neg_v, gidx, 5, "model",
                                              payload=y2l[idx_l])
        return idx_g, y2_sel
    specs = dict(mesh=mesh,
                 in_specs=(P("data", None), P("model", None), P()),
                 check_vma=False)
    old_idx, old_y2, old_lam = shard_map(
        old_dense_body, out_specs=(P("data", None),) * 3, **specs)(
            X, X_db, lam_db)
    new_idx, new_y2 = shard_map(
        new_selection_body, out_specs=(P("data", None),) * 2, **specs)(
            X, X_db, lam_db)
    np.testing.assert_array_equal(np.asarray(new_idx), np.asarray(old_idx))
    np.testing.assert_array_equal(np.asarray(new_y2), np.asarray(old_y2))
    np.testing.assert_allclose(np.asarray(lam_dist), np.asarray(old_lam),
                               rtol=5e-7, atol=1e-7)
    # multi-slab (ragged chunk) keeps the same answer
    lam_multi = knn_predict_distributed(mesh, X_db, lam_db, X, k=5, chunk=13)
    np.testing.assert_allclose(np.asarray(lam_multi), np.asarray(old_lam),
                               rtol=5e-7, atol=1e-7)
    print("slab-sweep shard body equivalence OK")
    dense = rank_given_lambda(u, a, b, lam_dense, gamma, m2=8)
    dist = rank_distributed(mesh, u, a, b, lam_dense, gamma, m2=8)
    np.testing.assert_array_equal(np.asarray(dist.perm), np.asarray(dense.perm))
    np.testing.assert_allclose(dist.utility, dense.utility, rtol=1e-5)
    np.testing.assert_allclose(dist.exposure, dense.exposure, rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_array_equal(np.asarray(dist.compliant),
                                  np.asarray(dense.compliant))
    print("distributed serving equivalence OK")

    # ---- serving engine with the distributed bucket executor --------------
    from repro.serving import ServingEngine, make_stream, Scenario
    eng_dist = ServingEngine(max_batch=8, max_wait_ms=1.0, executor="dist",
                             mesh=mesh, donate=False)
    eng_loc = ServingEngine(max_batch=8, max_wait_ms=1.0, donate=False)
    mix = (Scenario("feed", m1=200, m2=16, K=3, weight=2.0),
           Scenario("strip", m1=400, m2=8, K=5, weight=1.0))
    reqs = make_stream(mix, n_requests=32, seed=4)
    res_d = {r.rid: r for r in eng_dist.serve_stream(reqs)}
    res_l = {r.rid: r for r in eng_loc.serve_stream(reqs)}
    assert eng_dist.metrics.summary()["compiles_post_warmup"] == 0
    for rid in res_l:
        np.testing.assert_array_equal(res_d[rid].perm, res_l[rid].perm)
        np.testing.assert_allclose(res_d[rid].exposure, res_l[rid].exposure,
                                   rtol=1e-5, atol=1e-6)
        assert res_d[rid].compliant == res_l[rid].compliant
    print("engine dist executor OK")

    # ---- shard_map EP MoE == dense MoE (§Perf variant B), fwd + grads -----
    from dataclasses import replace
    from repro.models.transformer import LMConfig, TransformerLM
    from repro.distributed.sharding import LM_RULES
    cfgm = LMConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_head=8,
                    d_ff=64, vocab=64, moe=True, n_experts=8, top_k=2,
                    d_ff_moe=32, dtype=jnp.float32, param_dtype=jnp.float32,
                    remat="none", dense_attn_threshold=4096,
                    capacity_factor=8.0)
    cfgs = replace(cfgm, moe_dispatch="shmap")
    md, ms = TransformerLM(cfgm), TransformerLM(cfgs)
    pm = md.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (4, 16), 0, 64)
    def l1(p): return md.loss(p, {"tokens": toks, "labels": toks})[0]
    def l2(p): return ms.loss(p, {"tokens": toks, "labels": toks})[0]
    g1 = jax.jit(jax.grad(l1))(pm)
    with use_mesh_rules(mesh, LM_RULES):
        g2 = jax.jit(jax.grad(l2))(pm)
    worst = max(jax.tree.leaves(jax.tree.map(
        lambda a_, b_: float(jnp.max(jnp.abs(a_ - b_))), g1, g2)))
    assert worst < 3e-4, worst
    print("shmap MoE grad equivalence OK", worst)

    # ---- elastic checkpoint restore onto a DIFFERENT mesh -----------------
    import tempfile
    from jax.sharding import NamedSharding, PartitionSpec as P2
    from repro.checkpoint import CheckpointStore
    mesh_a = jax.make_mesh((2, 4), ("data", "model"))
    mesh_b = jax.make_mesh((4, 2), ("data", "model"))
    w = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                       NamedSharding(mesh_a, P2("data", "model")))
    with tempfile.TemporaryDirectory() as d:
        store = CheckpointStore(d)
        store.save(1, {"w": w})
        like = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
        shardings = {"w": NamedSharding(mesh_b, P2("data", "model"))}
        restored, _ = store.restore(like, shardings=shardings)
        np.testing.assert_allclose(np.asarray(restored["w"]),
                                   np.arange(64.0).reshape(8, 8))
        assert restored["w"].sharding.mesh.shape["data"] == 4
    print("elastic reshard OK")
""")


@pytest.mark.slow
def test_multidevice_semantics():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", _PROG], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))),
                       timeout=420)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    for marker in ("sharded_knn_topk OK", "sharded_score_topk OK",
                   "compressed_psum OK", "dryrun cell OK",
                   "paper serve SPMD OK",
                   "distributed serving equivalence OK",
                   "slab-sweep shard body equivalence OK",
                   "engine dist executor OK",
                   "shmap MoE grad equivalence OK",
                   "elastic reshard OK"):
        assert marker in r.stdout
