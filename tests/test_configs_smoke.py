"""Per-architecture smoke tests (deliverable f): every assigned arch
instantiates a REDUCED same-family config and runs one real forward/train
step on CPU, asserting output shapes and finiteness. Full configs are
exercised abstractly in test_dryrun_cells.py / launch/dryrun.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs, get_arch
from repro.data.batches import (
    make_deepfm_batch,
    make_lm_batch,
    make_molecule_batch,
    make_random_graph,
    make_seqrec_batch,
)
from repro.optim import adam_init

LM_ARCHS = ["kimi-k2-1t-a32b", "llama4-scout-17b-a16e", "phi3-medium-14b",
            "llama3.2-1b", "mistral-nemo-12b"]
RECSYS_ARCHS = ["deepfm", "sasrec", "bert4rec", "mind"]


def test_all_assigned_archs_registered():
    expected = set(LM_ARCHS + RECSYS_ARCHS + ["meshgraphnet", "paper-ranking"])
    assert expected <= set(all_archs())


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    from repro.models.transformer import TransformerLM
    spec = get_arch(arch)
    cfg = spec.make_config(full=False)
    model = TransformerLM(cfg)
    params = model.init(jax.random.key(0))
    batch = make_lm_batch(jax.random.key(1), batch=2, seq=16, vocab=cfg.vocab)
    logits, aux = model.forward(params, batch["tokens"])
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    opt = adam_init(params, cfg.moment_dtype)
    params2, _, metrics = model.train_step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_prefill_decode(arch):
    from repro.models.transformer import TransformerLM
    spec = get_arch(arch)
    cfg = spec.make_config(full=False)
    model = TransformerLM(cfg)
    params = model.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(2), (2, 8), 0, cfg.vocab)
    cache, logits = model.prefill(params, tokens)
    assert logits.shape == (2, cfg.vocab)
    dcache = model.make_cache(2, 16)
    dcache = {k: v.at[:, :, :8].set(cache[k]) for k, v in dcache.items()}
    logits2, dcache = model.decode_step(
        params, dcache, tokens[:, -1], jnp.asarray(8))
    assert logits2.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits2)))


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_smoke_train_and_serve(arch):
    from repro.models.recsys import RECSYS_REGISTRY
    spec = get_arch(arch)
    cfg = spec.make_config(full=False)
    model = RECSYS_REGISTRY[cfg.kind](cfg)
    params = model.init(jax.random.key(0))
    B = 8
    if cfg.kind == "deepfm":
        batch = make_deepfm_batch(jax.random.key(1), batch=B,
                                  n_sparse=cfg.n_sparse,
                                  field_vocab=cfg.field_vocab)
        scores = model.serve(params, batch["ids"])
    else:
        batch = make_seqrec_batch(jax.random.key(1), batch=B,
                                  seq_len=cfg.seq_len, n_items=cfg.n_items,
                                  n_neg=7, kind=cfg.kind, n_mask=4)
        scores = model.serve(params, batch["seq"], jnp.zeros((B,), jnp.int32))
    assert scores.shape == (B,)
    opt = adam_init(params)
    _, _, metrics = model.train_step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))


def test_meshgraphnet_smoke():
    from dataclasses import replace

    from repro.models.gnn import MeshGraphNet
    spec = get_arch("meshgraphnet")
    cfg = replace(spec.make_config(full=False), d_node_in=10, d_edge_in=4,
                  d_out=3)
    model = MeshGraphNet(cfg)
    params = model.init(jax.random.key(0))
    g = make_random_graph(jax.random.key(1), n_nodes=30, n_edges=60,
                          d_node=10, d_edge=4, d_out=3)
    out = model.forward(params, g)
    assert out.shape == (30, 3)
    assert bool(jnp.all(jnp.isfinite(out)))
    opt = adam_init(params)
    _, _, metrics = model.train_step(params, opt, g)
    assert np.isfinite(float(metrics["loss"]))
    # batched molecule mode
    gb = make_molecule_batch(jax.random.key(2), batch=3, n_nodes=6,
                             n_edges=10, d_node=10, d_edge=4, d_out=3)
    loss, _ = model.loss(params, gb)
    assert np.isfinite(float(loss))


def test_paper_ranking_smoke():
    """The paper arch's reduced cells run with real arrays on CPU."""
    from repro.configs.paper import PAPER_SMOKE_CELLS, build_paper
    from repro.distributed.sharding import use_mesh_rules
    spec = get_arch("paper-ranking")
    cfg = spec.make_config(full=False)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for cell in PAPER_SMOKE_CELLS:
        low = build_paper(cfg, cell, mesh)
        args = [jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype) + 0.1
            if jnp.issubdtype(s.dtype, jnp.floating)
            else jnp.zeros(s.shape, s.dtype), a) for a in low.args]
        with use_mesh_rules(mesh, low.rules):
            out = low.fn(*args)
        assert all(bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))
                   for x in jax.tree.leaves(out)
                   if jnp.issubdtype(x.dtype, jnp.floating))
