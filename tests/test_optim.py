"""Optimizer substrate: Adam, clipping, schedules, compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.optim import (
    adam_init,
    adam_update,
    clip_by_global_norm,
    compress_int8,
    cosine_schedule,
    decompress_int8,
    linear_warmup_cosine,
)

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def test_adam_first_step_is_lr_sized():
    """With bias correction, |first update| == lr for any gradient scale."""
    params = {"w": jnp.asarray([1.0, -2.0])}
    grads = {"w": jnp.asarray([100.0, -0.001])}
    opt = adam_init(params)
    new, opt = adam_update(grads, opt, params, lr=0.1)
    np.testing.assert_allclose(np.abs(np.asarray(new["w"] - params["w"])),
                               0.1, rtol=1e-4)


def test_adam_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adam_init(params)
    for _ in range(300):
        g = {"w": 2 * params["w"]}
        params, opt = adam_update(g, opt, params, lr=0.05)
    np.testing.assert_allclose(params["w"], 0.0, atol=1e-2)


def test_adam_bf16_moments_close_to_fp32():
    params = {"w": jnp.ones((64,))}
    g = {"w": jnp.linspace(-1, 1, 64)}
    o32 = adam_init(params, moment_dtype=jnp.float32)
    o16 = adam_init(params, moment_dtype=jnp.bfloat16)
    p32, _ = adam_update(g, o32, params, lr=0.1)
    p16, _ = adam_update(g, o16, params, lr=0.1)
    np.testing.assert_allclose(p16["w"], p32["w"], atol=1e-2)


def test_weight_decay():
    params = {"w": jnp.asarray([1.0])}
    opt = adam_init(params)
    new, _ = adam_update({"w": jnp.asarray([0.0])}, opt, params, lr=0.1,
                         weight_decay=0.1)
    assert float(new["w"][0]) < 1.0


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(gn), 10.0, rtol=1e-5)
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped)))
    np.testing.assert_allclose(float(total), 1.0, rtol=1e-5)
    # under the threshold: untouched
    c2, _ = clip_by_global_norm(g, 100.0)
    np.testing.assert_allclose(c2["a"], g["a"])


def test_schedules():
    cos = cosine_schedule(1.0, 100)
    assert float(cos(jnp.asarray(0))) == 1.0
    assert abs(float(cos(jnp.asarray(100))) - 0.1) < 1e-5
    wc = linear_warmup_cosine(1.0, 10, 100)
    assert float(wc(jnp.asarray(0))) == 0.0
    assert abs(float(wc(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(wc(jnp.asarray(5))) == 0.5


@given(st.integers(0, 1000))
def test_int8_compression_error_bound(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(64,)) * rng.uniform(0.01, 100),
                    jnp.float32)
    q, scale = compress_int8(x)
    err = np.abs(np.asarray(decompress_int8(q, scale) - x))
    assert err.max() <= float(scale) / 2 + 1e-6  # half-step quantization


def test_error_feedback_preserves_signal():
    """With error feedback, repeated compression of a constant gradient
    recovers the full magnitude on average."""
    from repro.optim.compression import compress_int8, decompress_int8
    g = jnp.asarray([1e-4, 1.0])         # tiny component would vanish alone
    residual = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    n = 1000                             # quantum is scale/127 ~ 0.008;
    for _ in range(n):                   # need enough steps to emit several
        xc = g + residual
        q, s = compress_int8(xc)
        deq = decompress_int8(q, s)
        residual = xc - deq
        acc = acc + deq
    np.testing.assert_allclose(acc / n, g, rtol=0.1, atol=1e-6)
